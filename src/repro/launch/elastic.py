"""Elastic multi-host CALL: failure detection, survivor re-meshing,
coordinator survival, and scale-up re-admission.

The static mesh layer (`launch.mesh.run_mesh`) dies with its first
lost host: `MeshSpec.build()` wants its exact device count and a psum
with a dead peer either raises or hangs.  This module makes the run
survive: detect the death, re-mesh the survivors, adopt the orphaned
shard extents, and resume the scanned trajectory from the replicated
iterate — no restart, no lost rounds (at worst the current chunk is
re-executed).  A recovered or replacement rank can come BACK mid-run:
it announces itself on the control plane and is re-admitted at the
next chunk boundary (`train.elastic.rebalance_plan` hands shards back,
the mesh grows W -> W+1, the joiner restores the replicated iterate
and the RNG fast-forward).

Failure model (empirically pinned on the gloo CPU backend; see
docs/multihost.md "Elastic recovery"):

  * Survivor sub-mesh collectives WORK after a peer death — gloo
    happily builds new communicators over the remaining processes —
    as long as backend bring-up finished while everyone was alive.
  * A collective that INCLUDES a dead rank is unreliable: it may raise
    quickly or hang indefinitely, depending on rank.  Survivors must
    therefore never enter a collective with a dead peer — detection is
    host-side, at chunk boundaries, via the control plane.
  * The coordination service itself would declare the dead task
    missing after ~100 s and then TERMINATE the survivors; elastic
    runs must be brought up with `init_distributed(elastic=True)`,
    which raises that service threshold out of the way.
  * Losing rank 0 is survivable IFF the control plane outlives it:
    either the file-backed store (`ElasticConfig.control="file:..."`)
    or the coordination-service KV with the service hosted OUTSIDE the
    ranks (`--service-host` + `init_distributed(external_service=
    True)`).  The lowest live survivor then PROMOTES itself to
    verdict-issuer (first-wins fence claim, so a zombie ex-leader can
    never split-brain).  With the classic in-rank-0 service, rank-0
    loss remains the cold `checkpoint_dir` fallback.

Execution structure: the T-round trajectory runs as chunks of
`check_every` rounds through the stacked scanned driver
(`pscope.run_stacked_scanned` — zero-sync within a chunk).  At every
chunk boundary each rank publishes a round marker to the control
plane; the leader (lowest surviving rank) collects them, consults the
heartbeat table when a marker is missing, folds in any pending join
requests, and publishes a verdict — via first-write-wins claim, so
every survivor obeys the SAME verdict even across a leader change:
continue, or re-mesh at epoch+1 and resume — from the just-computed
iterate when every survivor finished the chunk, or rolled back to the
chunk-start iterate (which everyone holds, replicated) when a
survivor's collective blew up mid-chunk.  The re-mesh barrier is
itself leader-verdicted, so a death DURING recovery just triggers
another re-mesh round instead of a deadlock.  While survivors wait at
that barrier, the orphan-shard `local_slice` mmaps and stacked
slot-tables build on a background thread — the rebuild hides behind
the barrier wait (`ElasticRunResult.remesh_overlap_saved_s`).  The RNG
split chain is fast-forwarded per segment (`start_round`), so the
recovered trajectory equals the uninterrupted p-worker run within
fp32 — placement transparency.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import signal
import sys
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.launch.control import (DistributedKVControlPlane,
                                  LocalControlPlane, claim_fence,
                                  join_request_key, make_control_plane,
                                  newest_fence, publish_progress,
                                  read_progress, validate_control_spec)

Ownership = Dict[int, Tuple[int, ...]]

# env knob: comma-separated "<rank>:<round>[:barrier]" entries — each
# named rank SIGKILLs itself at the chunk boundary AFTER completing the
# chunk containing <round>, before its marker write (or, with the
# ":barrier" suffix, after obeying a re-mesh verdict but right BEFORE
# entering the re-mesh barrier — the death-during-recovery schedule).
# Deterministic fault injection for tests/CI/benchmarks: the death
# lands between collectives, so survivors detect it at the marker
# barrier instead of inside a psum.
KILL_ENV = "REPRO_ELASTIC_KILL"

# env knob: "<rank>:<depart_round>:<rejoin_round>" — that rank goes
# protocol-dead (stops heartbeats/markers/collectives) at the chunk
# boundary after <depart_round>, is declared dead and re-meshed out,
# then announces itself on the control plane once the run reaches
# <rejoin_round> and is re-admitted.  This is the "park/revive"
# simulation of losing and recovering a host: a genuinely SIGKILLed
# process cannot re-enter a jax.distributed job (the service refuses
# the reconnect), so a replacement PROCESS needs the cold checkpoint
# path — but a recovered HOST is exactly this schedule.
DEPART_ENV = "REPRO_ELASTIC_DEPART"


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Knobs of the elastic run layer (validated at construction).

    check_every           rounds per chunk — the detection granularity:
                          a failure costs at most this many re-executed
                          rounds plus the re-mesh latency
    heartbeat_interval_s  how often each rank bumps its liveness counter
    heartbeat_timeout_s   counter unchanged for this long => rank is dead
    marker_timeout_s      how long the leader waits for chunk markers
                          before consulting the heartbeat table
    verdict_timeout_s     how long followers wait for the leader's
                          verdict before promoting a new leader (on a
                          coordinator-survivable control plane) or
                          giving up (generously > marker_timeout_s)
    poll_interval_s       control-plane polling period
    namespace             key prefix (disambiguates concurrent runs)
    checkpoint_dir        cold-fallback directory: the leader
                          checkpoints the iterate at chunk boundaries,
                          and a fresh run resumes from the newest step
                          when in-memory recovery was impossible
    checkpoint_every      chunks between checkpoint saves (0 = off even
                          with a directory set)
    control               control-plane backend: "kv" (the
                          jax.distributed coordination-service store —
                          survives rank 0 only with an external
                          --service-host), "file:<path>" (NFS/local
                          directory, survives any single failure), or
                          "local" (in-process; single-rank runs)
    """

    check_every: int = 1
    heartbeat_interval_s: float = 0.25
    heartbeat_timeout_s: float = 4.0
    marker_timeout_s: float = 6.0
    verdict_timeout_s: float = 120.0
    poll_interval_s: float = 0.05
    namespace: str = "elastic"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    control: str = "kv"

    def __post_init__(self):
        if self.check_every <= 0:
            raise ValueError(
                f"check_every must be >= 1 (got {self.check_every}): "
                f"chunk boundaries are the only failure-detection points")
        if self.heartbeat_interval_s <= 0 or self.poll_interval_s <= 0 \
                or self.marker_timeout_s <= 0:
            raise ValueError(
                f"heartbeat_interval_s, poll_interval_s and "
                f"marker_timeout_s must be positive (got "
                f"{self.heartbeat_interval_s}, {self.poll_interval_s}, "
                f"{self.marker_timeout_s})")
        if self.heartbeat_timeout_s <= self.heartbeat_interval_s:
            raise ValueError(
                f"heartbeat_timeout_s ({self.heartbeat_timeout_s}) must "
                f"exceed heartbeat_interval_s "
                f"({self.heartbeat_interval_s}): a counter published "
                f"every interval cannot be judged stale sooner, so no "
                f"death would ever be detected")
        if self.verdict_timeout_s < self.marker_timeout_s:
            raise ValueError(
                f"verdict_timeout_s ({self.verdict_timeout_s}) is the "
                f"hard deadline and must cover marker_timeout_s "
                f"({self.marker_timeout_s})")
        if self.verdict_timeout_s <= self.heartbeat_timeout_s:
            raise ValueError(
                f"verdict_timeout_s ({self.verdict_timeout_s}) must "
                f"exceed heartbeat_timeout_s "
                f"({self.heartbeat_timeout_s}): a dead rank could never "
                f"be declared before the hard deadline, so every "
                f"failure would abort the run instead of re-meshing")
        if self.checkpoint_every < 0:
            raise ValueError(f"checkpoint_every must be >= 0 (got "
                             f"{self.checkpoint_every})")
        validate_control_spec(self.control)


# ---------------------------------------------------------------------------
# KV store: PR-7 names, now thin aliases of launch.control backends
# ---------------------------------------------------------------------------

class LocalKV(LocalControlPlane):
    """Dict-backed stand-in (single-process runs and protocol tests)."""


class DistributedKV(DistributedKVControlPlane):
    """The coordination-service KV store of the running
    `jax.distributed` job (see `launch.control`)."""


# ---------------------------------------------------------------------------
# Heartbeats + failure detection
# ---------------------------------------------------------------------------

class Heartbeat(threading.Thread):
    """Background publisher: bumps `{ns}/hb/{rank}` every interval.

    The value is a monotonically increasing counter, NOT a wall-clock
    timestamp — liveness is judged by whether the counter ADVANCES (as
    observed on the reader's own clock), so cross-host clock skew can
    never fake a death or hide one.
    """

    def __init__(self, kv, ns: str, rank: int, interval_s: float):
        super().__init__(daemon=True, name=f"elastic-hb-{rank}")
        self._kv = kv
        self._key = f"{ns}/hb/{rank}"
        self._interval = interval_s
        self._stop = threading.Event()
        self._n = 0

    def run(self) -> None:
        while not self._stop.is_set():
            self._n += 1
            try:
                self._kv.set(self._key, str(self._n))
            except Exception:      # noqa: BLE001 — a dying service; the
                return             # detector will see the stall
            self._stop.wait(self._interval)

    def beat_once(self) -> None:
        """Synchronous first beat (call before the run starts so the
        detector has seen every rank at least once)."""
        self._n += 1
        self._kv.set(self._key, str(self._n))

    def stop(self) -> None:
        self._stop.set()


class FailureDetector:
    """Stale-heartbeat detector, local-clock based.

    Tracks, per rank, the last observed counter value and WHEN (by this
    process's monotonic clock) it last changed; `stale()` returns the
    ranks whose counter hasn't advanced within the timeout.  A rank
    never seen at all counts from the detector's construction time, so
    a peer that died during bring-up is still caught.
    """

    def __init__(self, kv, ns: str, ranks: Sequence[int],
                 timeout_s: float):
        self._kv = kv
        self._prefix = f"{ns}/hb/"
        self._timeout = timeout_s
        t0 = time.monotonic()
        self._seen: Dict[int, Tuple[Optional[str], float]] = {
            int(r): (None, t0) for r in ranks}

    def refresh(self) -> None:
        now = time.monotonic()
        table = self._kv.list(self._prefix)
        for key, val in table.items():
            try:
                rank = int(key.rsplit("/", 1)[-1])
            except ValueError:
                continue
            prev = self._seen.get(rank)
            if prev is None or prev[0] != val:
                self._seen[rank] = (val, now)

    def stale(self, among: Optional[Sequence[int]] = None) -> List[int]:
        self.refresh()
        now = time.monotonic()
        ranks = self._seen if among is None else among
        return sorted(r for r in ranks
                      if now - self._seen[int(r)][1] > self._timeout)


# ---------------------------------------------------------------------------
# Chunk-boundary consensus: markers + the leader's verdict
# ---------------------------------------------------------------------------

def _marker_prefix(ns: str, epoch: int, chunk: int) -> str:
    return f"{ns}/e{epoch}/done/c{chunk}/"


def _verdict_prefix(ns: str, epoch: int, chunk: int) -> str:
    # NOTE: the verdict lives at "<prefix>v", a DIRECTORY-style key —
    # the coordination service's key_value_dir_get only returns keys
    # strictly under "arg/", so an exact-key poll would never see it
    return f"{ns}/e{epoch}/verdict/c{chunk}/"


def _ready_prefix(ns: str, epoch: int) -> str:
    return f"{ns}/e{epoch}/ready/"


def _go_prefix(ns: str, epoch: int) -> str:
    return f"{ns}/e{epoch}/go/"


def publish_marker(kv, ns: str, epoch: int, chunk: int, rank: int,
                   status: str, round_end: int) -> None:
    kv.set(_marker_prefix(ns, epoch, chunk) + str(rank),
           json.dumps({"status": status, "round": round_end}))


def _decide_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int,
                    survivors: Sequence[int], detector: FailureDetector,
                    chunk_start: int, chunk_end: int) -> dict:
    """The leader's decision logic (no publication — see callers).

    Waits for every survivor's marker; once `marker_timeout_s` passes,
    missing ranks are declared dead as soon as their heartbeats go
    stale (a slow-but-alive rank keeps beating and keeps being waited
    for).

      * every survivor ok            -> {"op": "continue"}  (resume ==
        chunk_end; each rank keeps its just-computed iterate)
      * dead ranks, survivors all ok -> {"op": "remesh",
        "resume_round": chunk_end}
      * any survivor reported a failed chunk (its collective raised
        mid-chunk) -> {"op": "remesh", "resume_round": chunk_start} —
        every survivor rolls back to the replicated chunk-start
        iterate, and the chunk is re-executed on the new mesh.
    """
    prefix = _marker_prefix(cfg.namespace, epoch, chunk)
    deadline = time.monotonic() + cfg.marker_timeout_s
    hard_deadline = time.monotonic() + cfg.verdict_timeout_s
    dead: List[int] = []
    while True:
        markers = {}
        for key, val in kv.list(prefix).items():
            try:
                markers[int(key.rsplit("/", 1)[-1])] = json.loads(val)
            except (ValueError, json.JSONDecodeError):
                continue
        missing = [r for r in survivors if r not in markers]
        if not missing:
            break
        if time.monotonic() > deadline:
            dead = [r for r in detector.stale(missing)]
            if sorted(dead) == sorted(missing):
                break
        if time.monotonic() > hard_deadline:
            raise RuntimeError(
                f"elastic: ranks {missing} neither reported chunk "
                f"{chunk} (epoch {epoch}) nor went heartbeat-stale "
                f"within {cfg.verdict_timeout_s}s — likely a hung "
                f"collective; in-memory recovery is impossible "
                f"(cold fallback: checkpoint_dir)")
        time.sleep(cfg.poll_interval_s)

    failed = [r for r, m in markers.items() if m.get("status") != "ok"]
    if not dead and not failed:
        return {"op": "continue", "resume_round": chunk_end, "dead": []}
    # a failed chunk on a survivor without a detected death means
    # someone died mid-collective: wait for the heartbeat table to
    # name the corpse
    while failed and not dead:
        dead = detector.stale([r for r in survivors
                               if r not in failed])
        if time.monotonic() > hard_deadline:
            raise RuntimeError(
                f"elastic: survivors {failed} reported failed "
                f"chunks but no rank went heartbeat-stale — "
                f"cannot attribute the failure; aborting")
        if not dead:
            time.sleep(cfg.poll_interval_s)
    resume = chunk_start if failed else chunk_end
    return {"op": "remesh", "resume_round": resume,
            "dead": sorted(int(r) for r in dead)}


def leader_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int,
                   survivors: Sequence[int], detector: FailureDetector,
                   chunk_start: int, chunk_end: int) -> dict:
    """Rank 0's side of the chunk barrier (PR-7 entry point): decide
    and publish unconditionally.  The elastic driver itself goes
    through the fenced first-write-wins claim path instead (so a
    promoted leader and a zombie ex-leader can never publish competing
    verdicts); this plain form remains for single-leader callers and
    the protocol unit tests."""
    verdict = _decide_verdict(kv, cfg, epoch, chunk, survivors, detector,
                              chunk_start, chunk_end)
    kv.set(_verdict_prefix(cfg.namespace, epoch, chunk) + "v",
           json.dumps(verdict))
    return verdict


def follower_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int,
                     detector: FailureDetector) -> dict:
    """Block until rank 0 publishes the chunk verdict (PR-7 entry
    point — no leader promotion; see `_follow_chunk` for the
    promotion-capable path the driver uses)."""
    prefix = _verdict_prefix(cfg.namespace, epoch, chunk)
    deadline = time.monotonic() + cfg.verdict_timeout_s
    while True:
        table = kv.list(prefix)
        if table:
            return json.loads(next(iter(table.values())))
        if time.monotonic() > deadline:
            zero_stale = 0 in detector.stale([0])
            raise RuntimeError(
                "elastic: no verdict for chunk "
                f"{chunk} (epoch {epoch}) within "
                f"{cfg.verdict_timeout_s}s"
                + (" — rank 0 (the KV coordinator) is heartbeat-stale; "
                   "losing the coordinator is not survivable in-memory "
                   "(cold fallback: checkpoint_dir)" if zero_stale
                   else ""))
        time.sleep(cfg.poll_interval_s)


def remesh_barrier(kv, cfg: ElasticConfig, epoch: int, rank: int,
                   survivors: Sequence[int]) -> None:
    """KV-polling barrier among the survivors before the new epoch's
    first collective (PR-7 entry point: raises if a peer never
    arrives; the driver uses `remesh_barrier_checked`, which instead
    CONVERGES on a death during recovery)."""
    prefix = _ready_prefix(cfg.namespace, epoch)
    kv.set(prefix + str(rank), "1")
    deadline = time.monotonic() + cfg.verdict_timeout_s
    while True:
        present = set()
        for key in kv.list(prefix):
            try:
                present.add(int(key.rsplit("/", 1)[-1]))
            except ValueError:
                continue
        if all(r in present for r in survivors):
            return
        if time.monotonic() > deadline:
            missing = sorted(set(survivors) - present)
            raise RuntimeError(f"elastic: ranks {missing} never reached "
                               f"the epoch-{epoch} re-mesh barrier")
        time.sleep(cfg.poll_interval_s)


def remesh_barrier_checked(kv, cfg: ElasticConfig, epoch: int, rank: int,
                           survivors: Sequence[int],
                           detector: FailureDetector) -> List[int]:
    """Leader-verdicted re-mesh barrier: returns the ranks that DIED
    at the barrier instead of deadlocking on them.

    Every survivor publishes a ready key; the lowest LIVE survivor
    watches the set and claims (first-write-wins) a "go" verdict once
    either everyone arrived (`dead: []`) or the stragglers have gone
    heartbeat-stale (`dead: [...]`).  Every rank returns the same
    verdict's dead list; a non-empty result means the caller must run
    another re-mesh round (new failure_plan, epoch+1, barrier again) —
    the death-during-recovery cascade converges because each round
    strictly shrinks the survivor set.
    """
    ns = cfg.namespace
    kv.set(_ready_prefix(ns, epoch) + str(rank), "1")
    go_key = _go_prefix(ns, epoch) + "v"
    start = time.monotonic()
    deadline = start + cfg.verdict_timeout_s
    while True:
        table = kv.list(_go_prefix(ns, epoch))
        if table:
            return [int(r) for r in
                    json.loads(next(iter(table.values())))["dead"]]
        present = set()
        for key in kv.list(_ready_prefix(ns, epoch)):
            try:
                present.add(int(key.rsplit("/", 1)[-1]))
            except ValueError:
                continue
        missing = sorted(set(survivors) - present)
        live = [r for r in survivors if r not in detector.stale(survivors)]
        acting_leader = (live[0] if live else min(survivors)) == rank
        if acting_leader:
            if not missing:
                won = kv.try_claim(go_key, json.dumps({"dead": []}))
                return [int(r) for r in json.loads(won)["dead"]]
            if time.monotonic() - start > cfg.marker_timeout_s:
                stale_missing = detector.stale(missing)
                if sorted(stale_missing) == missing:
                    won = kv.try_claim(
                        go_key, json.dumps({"dead": missing}))
                    return [int(r) for r in json.loads(won)["dead"]]
        if time.monotonic() > deadline:
            raise RuntimeError(
                f"elastic: ranks {missing} neither reached the "
                f"epoch-{epoch} re-mesh barrier nor went "
                f"heartbeat-stale within {cfg.verdict_timeout_s}s")
        time.sleep(cfg.poll_interval_s)


# ---------------------------------------------------------------------------
# Fenced verdict claims, leader promotion, join admission
# ---------------------------------------------------------------------------

def _poll_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int) -> dict:
    prefix = _verdict_prefix(cfg.namespace, epoch, chunk)
    deadline = time.monotonic() + cfg.verdict_timeout_s
    while True:
        table = kv.list(prefix)
        if table:
            return json.loads(next(iter(table.values())))
        if time.monotonic() > deadline:
            raise RuntimeError(f"elastic: fenced out of the chunk-{chunk} "
                               f"(epoch {epoch}) verdict claim but no "
                               f"verdict ever appeared")
        time.sleep(cfg.poll_interval_s)


def _claim_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int,
                   me: int, verdict: dict, my_generation: int,
                   survivors: Sequence[int]) -> dict:
    """Publish `verdict` first-write-wins, under the fencing check: a
    leader that was fenced out (a newer generation exists and belongs
    to someone else STILL IN the survivor set) abdicates and obeys the
    fencer's verdict instead — the zombie-ex-leader guard.  A fence
    whose holder has since died or departed does not block the new
    leader: the holder cannot issue anything anymore."""
    g, holder = newest_fence(kv, cfg.namespace)
    if g > my_generation and holder != me and holder in set(survivors):
        return _poll_verdict(kv, cfg, epoch, chunk)
    won = kv.try_claim(_verdict_prefix(cfg.namespace, epoch, chunk) + "v",
                       json.dumps(verdict))
    return json.loads(won)


def _list_join_requests(kv, ns: str, exclude: Sequence[int]
                        ) -> Dict[int, str]:
    """Pending join announcements: rank -> join id, minus `exclude`."""
    out: Dict[int, str] = {}
    for key, val in kv.list(f"{ns}/join/").items():
        try:
            r = int(key.rsplit("/", 1)[-1])
        except ValueError:
            continue
        if r not in exclude:
            out[r] = val
    return out


def _fold_joiners(kv, cfg: ElasticConfig, base: dict,
                  survivors: Sequence[int], ownership: Ownership,
                  w, w_new, chunk_end: int
                  ) -> Tuple[dict, Dict[int, str]]:
    """Fold pending join requests into the chunk verdict.

    With joiners present the verdict becomes a re-mesh (even when
    nobody died) carrying everything a joiner cannot derive locally:
    the post-rebalance ownership map and the replicated iterate
    (base64 fp32 — the chunk-end iterate when the chunk was clean, the
    chunk-start one under a rollback).  Returns (verdict, join ids) —
    admissions are only published AFTER the claim resolves, from the
    WINNING verdict (`_publish_admissions`), so a joiner can never act
    on a verdict that lost the race.
    """
    from repro.train.elastic import failure_plan, rebalance_plan

    joins = _list_join_requests(
        kv, cfg.namespace,
        exclude=list(survivors) + list(base["dead"]))
    if not joins:
        return base, {}
    joiners = sorted(joins)
    own = dict(ownership)
    if base["dead"]:
        own = failure_plan(own, base["dead"])
    own = rebalance_plan(own, joiners)
    w_ship = w_new if (int(base["resume_round"]) == int(chunk_end)
                       and w_new is not None) else w
    verdict = {
        "op": "remesh", "resume_round": int(base["resume_round"]),
        "dead": list(base["dead"]), "joiners": joiners,
        "ownership": {str(r): [int(x) for x in ws]
                      for r, ws in own.items()},
        "w_b64": base64.b64encode(
            np.asarray(w_ship, np.float32).tobytes()).decode("ascii"),
    }
    return verdict, joins


def _publish_admissions(kv, cfg: ElasticConfig, epoch: int, winner: dict,
                        survivors: Sequence[int],
                        join_ids: Dict[int, str]) -> None:
    """Write each admitted joiner's pickup record, derived from the
    verdict that actually WON the claim (identical no matter which
    claimant writes it)."""
    joiners = winner.get("joiners") or []
    if not joiners:
        return
    ns = cfg.namespace
    nxt = sorted(set(int(r) for r in survivors
                     if r not in winner["dead"]) | set(joiners))
    for r in joiners:
        jid = join_ids.get(int(r))
        if jid is None:
            continue               # this claimant never saw the request
        admit = {"epoch_next": int(epoch) + 1,
                 "resume_round": int(winner["resume_round"]),
                 "survivors": nxt,
                 "ownership": winner["ownership"],
                 "w_b64": winner["w_b64"]}
        kv.set(f"{ns}/admit/{r}/{jid}", json.dumps(admit))
        kv.delete(join_request_key(ns, int(r)))


def _lead_chunk(kv, cfg: ElasticConfig, epoch: int, chunk: int, me: int,
                survivors: Sequence[int], detector: FailureDetector,
                chunk_start: int, chunk_end: int, ownership: Ownership,
                w, w_new, fence_generation: int) -> dict:
    """The driver's leader path: decide, fold joins, claim (fenced)."""
    base = _decide_verdict(kv, cfg, epoch, chunk, survivors, detector,
                           chunk_start, chunk_end)
    verdict, join_ids = _fold_joiners(kv, cfg, base, survivors, ownership,
                                      w, w_new, chunk_end)
    winner = _claim_verdict(kv, cfg, epoch, chunk, me, verdict,
                            fence_generation, survivors)
    _publish_admissions(kv, cfg, epoch, winner, survivors, join_ids)
    return winner


def _follow_chunk(kv, cfg: ElasticConfig, epoch: int, chunk: int, me: int,
                  survivors: Sequence[int], detector: FailureDetector,
                  chunk_start: int, chunk_end: int, ownership: Ownership,
                  w, w_new, fence_generation: int) -> Tuple[dict, int]:
    """The driver's follower path, WITH leader promotion.

    Polls for the chunk verdict; when the current leader (the lowest
    surviving rank) goes heartbeat-stale and the control plane
    survives coordinator loss, the lowest LIVE survivor claims the
    next fencing generation and — if it wins — issues the verdict
    itself (which will name the dead leader).  Returns
    (verdict, fence generation now held).
    """
    prefix = _verdict_prefix(cfg.namespace, epoch, chunk)
    deadline = time.monotonic() + cfg.verdict_timeout_s
    while True:
        table = kv.list(prefix)
        if table:
            return json.loads(next(iter(table.values()))), fence_generation
        leader = min(survivors)
        if leader in detector.stale([leader]) and \
                getattr(kv, "survives_coordinator", False):
            live = [r for r in survivors
                    if r not in detector.stale(survivors)]
            if live and live[0] == me:
                g, _ = newest_fence(kv, cfg.namespace)
                if claim_fence(kv, cfg.namespace, g + 1, me) == me:
                    fence_generation = g + 1
                    return _lead_chunk(
                        kv, cfg, epoch, chunk, me, survivors, detector,
                        chunk_start, chunk_end, ownership, w, w_new,
                        fence_generation), fence_generation
        if time.monotonic() > deadline:
            leader_stale = leader in detector.stale([leader])
            raise RuntimeError(
                f"elastic: no verdict for chunk {chunk} (epoch {epoch}) "
                f"within {cfg.verdict_timeout_s}s"
                + (f" — rank {leader} (the verdict issuer) is "
                   f"heartbeat-stale and this control plane does not "
                   f"survive the coordinator; losing it is "
                   f"not survivable in-memory "
                   f"(cold fallback: checkpoint_dir)" if leader_stale
                   else ""))
        time.sleep(cfg.poll_interval_s)


# ---------------------------------------------------------------------------
# The elastic driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticRunResult:
    """One `run_mesh_elastic` trajectory plus its recovery accounting."""

    w: np.ndarray
    values: np.ndarray
    nnz: np.ndarray
    comm_bytes_per_round: float
    events: Tuple[dict, ...]          # one per re-mesh (see below)
    epoch: int                        # final mesh epoch (0 = no failure)
    ownership: Ownership              # final worker->rank map
    worker_ids: Tuple[int, ...]       # workers THIS rank ended up owning
    survivors: Tuple[int, ...]
    seconds: float
    process_id: int
    num_processes: int
    rejoined: bool = False            # this rank departed and came back
    remesh_overlap_saved_s: float = 0.0   # host rebuild hidden behind
                                          # the re-mesh barrier wait

    @property
    def degraded(self) -> bool:
        return bool(self.events)


def _parse_kill_env() -> List[Tuple[int, int, bool]]:
    """[(rank, round, at_barrier), ...] from REPRO_ELASTIC_KILL."""
    raw = os.environ.get(KILL_ENV)
    if not raw:
        return []
    out = []
    for entry in raw.split(","):
        parts = entry.strip().split(":")
        if len(parts) not in (2, 3) or (len(parts) == 3
                                        and parts[2] != "barrier"):
            raise ValueError(f"bad {KILL_ENV} entry {entry!r} (want "
                             f"'rank:round' or 'rank:round:barrier')")
        out.append((int(parts[0]), int(parts[1]), len(parts) == 3))
    return out


def _parse_depart_env() -> Optional[Tuple[int, int, int]]:
    """(rank, depart_round, rejoin_round) from REPRO_ELASTIC_DEPART."""
    raw = os.environ.get(DEPART_ENV)
    if not raw:
        return None
    rank_s, k1_s, k2_s = raw.split(":")
    rank, k1, k2 = int(rank_s), int(k1_s), int(k2_s)
    if k2 <= k1:
        raise ValueError(f"{DEPART_ENV}={raw!r}: rejoin round must come "
                         f"after the depart round")
    return rank, k1, k2


def _sigkill_self() -> None:
    sys.stdout.flush()
    sys.stderr.flush()
    os.kill(os.getpid(), signal.SIGKILL)


def _survivor_mesh(survivors: Sequence[int], axis: str):
    """1-D mesh over the survivors' devices (one device per rank)."""
    import jax
    from jax.sharding import Mesh
    alive = set(survivors)
    devs = [d for d in jax.devices() if d.process_index in alive]
    if len(devs) != len(survivors):
        raise RuntimeError(
            f"elastic needs exactly one device per rank "
            f"({len(survivors)} survivors, {len(devs)} devices)")
    return Mesh(np.asarray(devs), (axis,))


def run_mesh_elastic(obj, reg, data, y, w0, cfg, *,
                     ecfg: Optional[ElasticConfig] = None,
                     axis: str = "workers") -> ElasticRunResult:
    """pSCOPE over a real mesh that SURVIVES losing — and regaining —
    ranks mid-run.

    Every process of the `jax.distributed` job calls this with the same
    arguments (`data`: a committed `ShardStore`, or worker-major
    `CSRMatrix` + labels for in-memory tests).  The caller must have
    brought the job up with `init_distributed(elastic=True)` — the
    default coordination-service liveness threshold would otherwise
    terminate the survivors ~100 s after a death.

    The trajectory runs in `ecfg.check_every`-round chunks through the
    stacked scanned driver; chunk boundaries are the detection points
    (see the module docstring for the protocol).  On a detected death
    the survivors re-mesh, adopt the orphaned workers' shard extents,
    and resume — the logical worker count p never changes, so the
    returned history matches the uninterrupted p-worker trajectory
    within fp32 (and is bit-identical across the surviving ranks; a
    re-admitted rank's history is the suffix from its resume round).

    After a degraded run the process MUST exit via `exit_now` (the
    distributed shutdown barrier would wait forever for the dead rank).
    """
    import jax

    from repro.core import pscope
    from repro.launch.mesh import (comm_bytes_per_round,
                                   prepare_stacked_host_blocks,
                                   stacked_worker_arrays)
    from repro.train.elastic import (failure_plan, initial_ownership,
                                     rebalance_plan)

    ecfg = ecfg or ElasticConfig()
    me = int(jax.process_index())
    nprocs = int(jax.process_count())
    survivors = list(range(nprocs))
    ns = ecfg.namespace

    from repro.datasets.shards import ShardStore
    if isinstance(data, ShardStore):
        p, d = int(data.p), int(data.d)
    else:
        p, d = int(data.vals.shape[0]), int(data.d)
    ownership: Ownership = initial_ownership(p, nprocs)
    cfg = dataclasses.replace(cfg, inner_path="lazy")

    kv = make_control_plane(ecfg.control, nprocs)
    hb = Heartbeat(kv, ns, me, ecfg.heartbeat_interval_s)
    hb.beat_once()
    hb.start()
    detector = FailureDetector(kv, ns, survivors,
                               ecfg.heartbeat_timeout_s)
    kills = _parse_kill_env()
    depart = _parse_depart_env()
    fence_gen = -1                  # no fencing generation claimed yet

    # cold fallback: resume from the newest checkpoint when one exists
    t0_round, w = 0, np.asarray(w0, np.float32)
    if ecfg.checkpoint_dir:
        from repro.train.checkpoint import latest_step, restore_checkpoint
        step = latest_step(ecfg.checkpoint_dir)
        if step is not None:
            tree, meta = restore_checkpoint(ecfg.checkpoint_dir, step)
            w = np.asarray(tree["w"], np.float32)
            t0_round = int(meta["metadata"]["round"])
    ckpt = None
    if ecfg.checkpoint_dir and ecfg.checkpoint_every > 0 and me == 0:
        from repro.train.checkpoint import AsyncCheckpointer
        ckpt = AsyncCheckpointer(ecfg.checkpoint_dir)

    mesh = _survivor_mesh(survivors, axis)
    arrays = stacked_worker_arrays(mesh, axis, ownership, data, y)

    T = cfg.outer_steps
    epoch = 0
    t = t0_round
    rejoined = False
    overlap_total = 0.0
    values: List[float] = []
    nnzs: List[int] = []
    events: List[dict] = []
    wall0 = time.perf_counter()

    def rebuild(pending_dead: List[int], pending_join: List[int],
                boundary: int, resume: int,
                own_override: Optional[Ownership] = None) -> None:
        """Re-mesh (possibly repeatedly, if ranks die AT the barrier):
        update membership + ownership, rebuild mesh and stacked arrays
        with the host work on a background thread, and record events."""
        nonlocal survivors, ownership, epoch, mesh, arrays, ckpt, \
            overlap_total
        while True:
            if 0 in pending_dead and \
                    not getattr(kv, "survives_coordinator", False):
                raise RuntimeError(
                    "elastic: rank 0 (the KV coordinator) died — not "
                    "survivable in-memory on this control plane (cold "
                    "fallback: checkpoint_dir; survivable alternatives: "
                    "control='file:...' or an external --service-host)")
            if me in pending_dead:
                raise RuntimeError(
                    f"elastic: rank {me} was itself declared dead by "
                    f"the verdict (stalled past heartbeat_timeout_s?) "
                    f"— refusing to split-brain the run")
            survivors = sorted(
                set(r for r in survivors if r not in pending_dead)
                | set(pending_join))
            if own_override is not None:
                ownership = dict(own_override)
                own_override = None
            else:
                if pending_dead:
                    ownership = failure_plan(ownership, pending_dead)
                if pending_join:
                    ownership = rebalance_plan(ownership, pending_join)
            epoch += 1
            for r, k, at_barrier in kills:
                if at_barrier and r == me and t < k <= boundary:
                    _sigkill_self()   # death DURING recovery
            t_re = time.perf_counter()
            box: dict = {}

            def bg_build():
                tb = time.perf_counter()
                try:
                    box["blocks"] = prepare_stacked_host_blocks(
                        ownership, data, y, ranks=[me])
                except BaseException as e:   # re-raised on the caller
                    box["err"] = e
                box["seconds"] = time.perf_counter() - tb

            builder = threading.Thread(target=bg_build, daemon=True,
                                       name="elastic-rebuild")
            builder.start()
            mesh = _survivor_mesh(survivors, axis)
            t_bar = time.perf_counter()
            with obs.span("elastic.remesh_barrier", epoch=int(epoch),
                          survivors=[int(r) for r in survivors]):
                newly_dead = remesh_barrier_checked(kv, ecfg, epoch, me,
                                                    survivors, detector)
            barrier_s = time.perf_counter() - t_bar
            builder.join()
            if "err" in box:
                raise box["err"]
            event = {
                "round": int(boundary), "resume_round": int(resume),
                "rounds_to_recover": int(boundary - resume),
                "dead": sorted(int(r) for r in pending_dead),
                "joiners": sorted(int(r) for r in pending_join),
                "epoch": int(epoch),
                "remesh_seconds": float(time.perf_counter() - t_re),
                "survivors": list(survivors),
                "ownership": {int(r): list(ws)
                              for r, ws in ownership.items()},
            }
            events.append(event)
            # fold recovery into the timeline as an instant marker (the
            # ownership map is in the JSONL audit trail, not the trace)
            obs.instant("elastic.remesh",
                        **{k: v for k, v in event.items()
                           if k != "ownership"})
            if newly_dead:
                pending_dead, pending_join = list(newly_dead), []
                continue
            arrays = stacked_worker_arrays(mesh, axis, ownership,
                                           host_blocks=box["blocks"])
            overlap_total += min(box["seconds"], barrier_s)
            break
        if me == min(survivors) and ckpt is None and \
                ecfg.checkpoint_dir and ecfg.checkpoint_every > 0:
            # checkpoint takeover: the promoted leader carries the
            # cold-fallback duty forward
            from repro.train.checkpoint import AsyncCheckpointer
            ckpt = AsyncCheckpointer(ecfg.checkpoint_dir)

    while t < T:
        chunk = t // ecfg.check_every   # deterministic: a re-admitted
        # rank derives the same marker/verdict keys as the incumbents
        chunk_len = min(ecfg.check_every, T - t)
        boundary = t + chunk_len
        if nprocs > 1 and me == min(survivors):
            publish_progress(kv, ns, round_=t, epoch=epoch, chunk=chunk,
                             survivors=survivors, ownership=ownership,
                             leader=me, fence_generation=fence_gen)
        seg_cfg = dataclasses.replace(cfg, outer_steps=chunk_len)
        vals_g, cols_g, y_g, slots_g, p_total = arrays
        status, w_new, seg_vals, seg_nnz = "ok", None, None, None
        try:
            with obs.span("elastic.chunk", chunk=int(chunk),
                          start_round=int(t), rounds=int(chunk_len),
                          epoch=int(epoch)):
                w_new, seg_vals, seg_nnz = pscope.run_stacked_scanned(
                    obj, reg, vals_g, cols_g, y_g, slots_g, w, seg_cfg,
                    mesh, axis=axis, start_round=t, p_total=p_total)
            # cumulative bytes-on-wire through this chunk's boundary
            obs.counter("comm_bytes",
                        comm_bytes_per_round(d) * float(boundary))
        except Exception as e:       # noqa: BLE001 — a peer died mid-
            status = f"failed: {e}"  # collective; report, roll back
            print(f"elastic: rank {me} chunk {chunk} (rounds {t}.."
                  f"{boundary}) compute failed: {e!r}",
                  file=sys.stderr, flush=True)
        for r, k, at_barrier in kills:
            if not at_barrier and r == me and t < k <= boundary:
                # die AFTER the chunk's collectives, BEFORE the marker:
                # the survivors detect it at the barrier, never inside
                # a psum.  SIGKILL — no atexit, no shutdown barrier.
                _sigkill_self()

        if depart is not None and depart[0] == me \
                and t < depart[1] <= boundary:
            # -- depart: go protocol-dead, park, then rejoin ----------
            _, _, rejoin_round = depart
            depart = None
            hb.stop()
            last_round, last_change = -1, time.monotonic()
            while True:              # parked: watch the leader's beacon
                prog = read_progress(kv, ns)
                if prog is not None:
                    if int(prog["round"]) >= rejoin_round:
                        break
                    if int(prog["round"]) != last_round:
                        last_round = int(prog["round"])
                        last_change = time.monotonic()
                if time.monotonic() - last_change > ecfg.verdict_timeout_s:
                    raise RuntimeError(
                        f"elastic: rank {me} parked for rejoin at round "
                        f"{rejoin_round} but the run stopped publishing "
                        f"progress — it likely finished first")
                time.sleep(ecfg.poll_interval_s)
            # announce BEFORE asking for admission: heartbeats must be
            # advancing again or the barrier would declare us dead
            hb = Heartbeat(kv, ns, me, ecfg.heartbeat_interval_s)
            hb.beat_once()
            hb.start()
            detector = FailureDetector(kv, ns, range(nprocs),
                                       ecfg.heartbeat_timeout_s)
            join_id = f"j{rejoin_round}"
            kv.set(join_request_key(ns, me), join_id)
            admit_prefix = f"{ns}/admit/{me}/"
            deadline = time.monotonic() + ecfg.verdict_timeout_s
            while True:
                raw = kv.list(admit_prefix).get(admit_prefix + join_id)
                if raw is not None:
                    admit = json.loads(raw)
                    break
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"elastic: rank {me} announced a rejoin but was "
                        f"never admitted within "
                        f"{ecfg.verdict_timeout_s}s")
                time.sleep(ecfg.poll_interval_s)
            w = np.frombuffer(base64.b64decode(admit["w_b64"]),
                              np.float32).copy()
            t = int(admit["resume_round"])
            epoch = int(admit["epoch_next"]) - 1   # rebuild() adds 1
            survivors = [int(r) for r in admit["survivors"]]
            own_new = {int(r): tuple(int(x) for x in ws)
                       for r, ws in admit["ownership"].items()}
            if me == min(survivors):
                # this rank resumes LEADERSHIP (it is the lowest rank
                # again): take over the newest fencing generation, or
                # the incumbent promoted leader's fence would read as
                # fencing us out while everyone else waits on us
                g, holder = newest_fence(kv, ns)
                while holder is not None and holder != me:
                    g += 1
                    holder = claim_fence(kv, ns, g, me)
                fence_gen = max(fence_gen, g)
            values, nnzs = [], []    # history restarts at the suffix
            rejoined = True
            rebuild([], [me], boundary=t, resume=t, own_override=own_new)
            continue

        if nprocs == 1:
            verdict = {"op": "continue", "resume_round": boundary,
                       "dead": []}
            if status != "ok":
                raise RuntimeError(f"elastic single-process chunk failed: "
                                   f"{status}")
        else:
            publish_marker(kv, ns, epoch, chunk, me,
                           "ok" if status == "ok" else "failed",
                           boundary)
            if me == min(survivors):
                verdict = _lead_chunk(kv, ecfg, epoch, chunk, me,
                                      survivors, detector, t, boundary,
                                      ownership, w, w_new, fence_gen)
            else:
                verdict, fence_gen = _follow_chunk(
                    kv, ecfg, epoch, chunk, me, survivors, detector, t,
                    boundary, ownership, w, w_new, fence_gen)

        if verdict["op"] == "continue":
            if not values:
                values.append(float(seg_vals[0]))
                nnzs.append(int(seg_nnz[0]))
            values.extend(float(v) for v in seg_vals[1:])
            nnzs.extend(int(x) for x in seg_nnz[1:])
            w, t = w_new, boundary
            if ckpt is not None and chunk % max(1, ecfg.checkpoint_every) \
                    == 0:
                ckpt.save(t, {"w": np.asarray(w)},
                          metadata={"round": int(t), "epoch": int(epoch)})
            continue

        # --- re-mesh ------------------------------------------------------
        dead = [int(r) for r in verdict["dead"]]
        joiners = [int(r) for r in verdict.get("joiners", [])]
        resume = int(verdict["resume_round"])
        if resume == boundary and status == "ok" and w_new is not None:
            if not values:
                values.append(float(seg_vals[0]))
                nnzs.append(int(seg_nnz[0]))
            values.extend(float(v) for v in seg_vals[1:])
            nnzs.extend(int(x) for x in seg_nnz[1:])
            w = w_new
        # else: keep the chunk-start iterate (rollback; history untouched)
        own_override = None
        if "ownership" in verdict:
            own_override = {int(r): tuple(int(x) for x in ws)
                            for r, ws in verdict["ownership"].items()}
        rebuild(dead, joiners, boundary, resume, own_override)
        t = resume

    if nprocs > 1 and me == min(survivors):
        publish_progress(kv, ns, round_=t, epoch=epoch,
                         chunk=t // ecfg.check_every, survivors=survivors,
                         ownership=ownership, leader=me,
                         fence_generation=fence_gen)
    hb.stop()
    if ckpt is not None:
        ckpt.wait()
    return ElasticRunResult(
        w=np.asarray(w), values=np.asarray(values, np.float64),
        nnz=np.asarray(nnzs, np.int64),
        comm_bytes_per_round=comm_bytes_per_round(d),
        events=tuple(events), epoch=epoch,
        ownership=dict(ownership),
        worker_ids=tuple(ownership.get(me, ())),
        survivors=tuple(survivors),
        seconds=time.perf_counter() - wall0,
        process_id=me, num_processes=nprocs,
        rejoined=rejoined,
        remesh_overlap_saved_s=float(overlap_total))


def exit_now(code: int = 0) -> None:
    """Flush and `os._exit` — the ONLY safe way to leave a degraded
    process: normal interpreter exit runs the `jax.distributed`
    shutdown barrier, which waits forever for the dead rank."""
    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(code)
