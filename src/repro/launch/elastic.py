"""Elastic multi-host CALL: failure detection + survivor re-meshing.

The static mesh layer (`launch.mesh.run_mesh`) dies with its first
lost host: `MeshSpec.build()` wants its exact device count and a psum
with a dead peer either raises or hangs.  This module makes the run
survive: detect the death, re-mesh the survivors, adopt the orphaned
shard extents, and resume the scanned trajectory from the replicated
iterate — no restart, no lost rounds (at worst the current chunk is
re-executed).

Failure model (empirically pinned on the gloo CPU backend; see
docs/multihost.md "Elastic recovery"):

  * Survivor sub-mesh collectives WORK after a peer death — gloo
    happily builds new communicators over the remaining processes —
    as long as backend bring-up finished while everyone was alive.
  * A collective that INCLUDES a dead rank is unreliable: it may raise
    quickly or hang indefinitely, depending on rank.  Survivors must
    therefore never enter a collective with a dead peer — detection is
    host-side, at chunk boundaries, via the coordinator KV store.
  * The coordination service itself would declare the dead task
    missing after ~100 s and then TERMINATE the survivors; elastic
    runs must be brought up with `init_distributed(elastic=True)`,
    which raises that service threshold out of the way.
  * Losing rank 0 is NOT survivable in-memory (it hosts the KV
    coordinator); that — and a hung collective — is what the cold
    checkpoint fallback is for.

Execution structure: the T-round trajectory runs as chunks of
`check_every` rounds through the stacked scanned driver
(`pscope.run_stacked_scanned` — zero-sync within a chunk).  At every
chunk boundary each rank publishes a round marker to the KV store; the
leader (rank 0) collects them, consults the heartbeat table when a
marker is missing, and publishes a verdict every survivor obeys:
continue, or re-mesh at epoch+1 (new ownership from
`train.elastic.failure_plan`, survivor mesh, orphan extents adopted via
`ShardStore.local_slice`) and resume — from the just-computed iterate
when every survivor finished the chunk, or rolled back to the chunk-
start iterate (which everyone holds, replicated) when a survivor's
collective blew up mid-chunk.  The RNG split chain is fast-forwarded
per segment (`start_round`), so the recovered trajectory equals the
uninterrupted p-worker run within fp32 — placement transparency.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import sys
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Ownership = Dict[int, Tuple[int, ...]]

# env knob: "<rank>:<round>" — that rank SIGKILLs itself at the chunk
# boundary AFTER completing the chunk containing <round>, before its
# marker write.  Deterministic fault injection for tests/CI/benchmarks:
# the death lands between collectives, so survivors detect it at the
# marker barrier instead of inside a psum.
KILL_ENV = "REPRO_ELASTIC_KILL"


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Knobs of the elastic run layer.

    check_every           rounds per chunk — the detection granularity:
                          a failure costs at most this many re-executed
                          rounds plus the re-mesh latency
    heartbeat_interval_s  how often each rank bumps its liveness counter
    heartbeat_timeout_s   counter unchanged for this long => rank is dead
    marker_timeout_s      how long the leader waits for chunk markers
                          before consulting the heartbeat table
    verdict_timeout_s     how long followers wait for the leader's
                          verdict (generously > marker_timeout_s; a
                          timeout here usually means rank 0 died, which
                          is not survivable in-memory)
    poll_interval_s       KV polling period
    namespace             KV key prefix (disambiguates concurrent runs)
    checkpoint_dir        cold-fallback directory: rank 0 checkpoints
                          the iterate at chunk boundaries, and a fresh
                          run resumes from the newest step when
                          in-memory recovery was impossible
    checkpoint_every      chunks between checkpoint saves (0 = off even
                          with a directory set)
    """

    check_every: int = 1
    heartbeat_interval_s: float = 0.25
    heartbeat_timeout_s: float = 4.0
    marker_timeout_s: float = 6.0
    verdict_timeout_s: float = 120.0
    poll_interval_s: float = 0.05
    namespace: str = "elastic"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1


# ---------------------------------------------------------------------------
# KV store: the jax.distributed coordinator service, or in-memory
# ---------------------------------------------------------------------------

class LocalKV:
    """Dict-backed stand-in (single-process runs and protocol tests)."""

    def __init__(self):
        self._d: Dict[str, str] = {}
        self._lock = threading.Lock()

    def set(self, key: str, value: str) -> None:
        with self._lock:
            self._d[key] = value

    def list(self, prefix: str) -> Dict[str, str]:
        with self._lock:
            return {k: v for k, v in self._d.items()
                    if k.startswith(prefix)}


class DistributedKV:
    """The coordination-service KV store of the running
    `jax.distributed` job.  Writes are visible to every live process;
    a dead process's keys persist (its heartbeat counter simply stops
    advancing — which is exactly the liveness signal)."""

    def __init__(self):
        from jax._src import distributed as _dist
        client = getattr(_dist.global_state, "client", None)
        if client is None:
            raise RuntimeError("DistributedKV needs an initialized "
                               "jax.distributed job (init_distributed)")
        self._client = client

    def set(self, key: str, value: str) -> None:
        self._client.key_value_set(key, value, allow_overwrite=True)

    def list(self, prefix: str) -> Dict[str, str]:
        return {k: v for k, v in self._client.key_value_dir_get(prefix)}


# ---------------------------------------------------------------------------
# Heartbeats + failure detection
# ---------------------------------------------------------------------------

class Heartbeat(threading.Thread):
    """Background publisher: bumps `{ns}/hb/{rank}` every interval.

    The value is a monotonically increasing counter, NOT a wall-clock
    timestamp — liveness is judged by whether the counter ADVANCES (as
    observed on the reader's own clock), so cross-host clock skew can
    never fake a death or hide one.
    """

    def __init__(self, kv, ns: str, rank: int, interval_s: float):
        super().__init__(daemon=True, name=f"elastic-hb-{rank}")
        self._kv = kv
        self._key = f"{ns}/hb/{rank}"
        self._interval = interval_s
        self._stop = threading.Event()
        self._n = 0

    def run(self) -> None:
        while not self._stop.is_set():
            self._n += 1
            try:
                self._kv.set(self._key, str(self._n))
            except Exception:      # noqa: BLE001 — a dying service; the
                return             # detector will see the stall
            self._stop.wait(self._interval)

    def beat_once(self) -> None:
        """Synchronous first beat (call before the run starts so the
        detector has seen every rank at least once)."""
        self._n += 1
        self._kv.set(self._key, str(self._n))

    def stop(self) -> None:
        self._stop.set()


class FailureDetector:
    """Stale-heartbeat detector, local-clock based.

    Tracks, per rank, the last observed counter value and WHEN (by this
    process's monotonic clock) it last changed; `stale()` returns the
    ranks whose counter hasn't advanced within the timeout.  A rank
    never seen at all counts from the detector's construction time, so
    a peer that died during bring-up is still caught.
    """

    def __init__(self, kv, ns: str, ranks: Sequence[int],
                 timeout_s: float):
        self._kv = kv
        self._prefix = f"{ns}/hb/"
        self._timeout = timeout_s
        t0 = time.monotonic()
        self._seen: Dict[int, Tuple[Optional[str], float]] = {
            int(r): (None, t0) for r in ranks}

    def refresh(self) -> None:
        now = time.monotonic()
        table = self._kv.list(self._prefix)
        for key, val in table.items():
            try:
                rank = int(key.rsplit("/", 1)[-1])
            except ValueError:
                continue
            prev = self._seen.get(rank)
            if prev is None or prev[0] != val:
                self._seen[rank] = (val, now)

    def stale(self, among: Optional[Sequence[int]] = None) -> List[int]:
        self.refresh()
        now = time.monotonic()
        ranks = self._seen if among is None else among
        return sorted(r for r in ranks
                      if now - self._seen[int(r)][1] > self._timeout)


# ---------------------------------------------------------------------------
# Chunk-boundary consensus: markers + the leader's verdict
# ---------------------------------------------------------------------------

def _marker_prefix(ns: str, epoch: int, chunk: int) -> str:
    return f"{ns}/e{epoch}/done/c{chunk}/"


def _verdict_prefix(ns: str, epoch: int, chunk: int) -> str:
    # NOTE: the verdict lives at "<prefix>v", a DIRECTORY-style key —
    # the coordination service's key_value_dir_get only returns keys
    # strictly under "arg/", so an exact-key poll would never see it
    return f"{ns}/e{epoch}/verdict/c{chunk}/"


def _ready_prefix(ns: str, epoch: int) -> str:
    return f"{ns}/e{epoch}/ready/"


def publish_marker(kv, ns: str, epoch: int, chunk: int, rank: int,
                   status: str, round_end: int) -> None:
    kv.set(_marker_prefix(ns, epoch, chunk) + str(rank),
           json.dumps({"status": status, "round": round_end}))


def leader_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int,
                   survivors: Sequence[int], detector: FailureDetector,
                   chunk_start: int, chunk_end: int) -> dict:
    """Rank 0's side of the chunk barrier.

    Waits for every survivor's marker; once `marker_timeout_s` passes,
    missing ranks are declared dead as soon as their heartbeats go
    stale (a slow-but-alive rank keeps beating and keeps being waited
    for).  The verdict — continue, or re-mesh with an explicit resume
    round — is published under an epoch/chunk-scoped key; every
    follower blocks on it, so all survivors act on identical state.

      * every survivor ok            -> {"op": "continue"}  (resume ==
        chunk_end; each rank keeps its just-computed iterate)
      * dead ranks, survivors all ok -> {"op": "remesh",
        "resume_round": chunk_end}
      * any survivor reported a failed chunk (its collective raised
        mid-chunk) -> {"op": "remesh", "resume_round": chunk_start} —
        every survivor rolls back to the replicated chunk-start
        iterate, and the chunk is re-executed on the new mesh.
    """
    prefix = _marker_prefix(ns := cfg.namespace, epoch, chunk)
    deadline = time.monotonic() + cfg.marker_timeout_s
    hard_deadline = time.monotonic() + cfg.verdict_timeout_s
    dead: List[int] = []
    while True:
        markers = {}
        for key, val in kv.list(prefix).items():
            try:
                markers[int(key.rsplit("/", 1)[-1])] = json.loads(val)
            except (ValueError, json.JSONDecodeError):
                continue
        missing = [r for r in survivors if r not in markers]
        if not missing:
            break
        if time.monotonic() > deadline:
            dead = [r for r in detector.stale(missing)]
            if sorted(dead) == sorted(missing):
                break
        if time.monotonic() > hard_deadline:
            raise RuntimeError(
                f"elastic: ranks {missing} neither reported chunk "
                f"{chunk} (epoch {epoch}) nor went heartbeat-stale "
                f"within {cfg.verdict_timeout_s}s — likely a hung "
                f"collective; in-memory recovery is impossible "
                f"(cold fallback: checkpoint_dir)")
        time.sleep(cfg.poll_interval_s)

    failed = [r for r, m in markers.items() if m.get("status") != "ok"]
    if not dead and not failed:
        verdict = {"op": "continue", "resume_round": chunk_end,
                   "dead": []}
    else:
        # a failed chunk on a survivor without a detected death means
        # someone died mid-collective: wait for the heartbeat table to
        # name the corpse
        while failed and not dead:
            dead = detector.stale([r for r in survivors
                                   if r not in failed])
            if time.monotonic() > hard_deadline:
                raise RuntimeError(
                    f"elastic: survivors {failed} reported failed "
                    f"chunks but no rank went heartbeat-stale — "
                    f"cannot attribute the failure; aborting")
            if not dead:
                time.sleep(cfg.poll_interval_s)
        resume = chunk_start if failed else chunk_end
        verdict = {"op": "remesh", "resume_round": resume,
                   "dead": sorted(int(r) for r in dead)}
    kv.set(_verdict_prefix(ns, epoch, chunk) + "v", json.dumps(verdict))
    return verdict


def follower_verdict(kv, cfg: ElasticConfig, epoch: int, chunk: int,
                     detector: FailureDetector) -> dict:
    """Block until rank 0 publishes the chunk verdict."""
    prefix = _verdict_prefix(cfg.namespace, epoch, chunk)
    deadline = time.monotonic() + cfg.verdict_timeout_s
    while True:
        table = kv.list(prefix)
        if table:
            return json.loads(next(iter(table.values())))
        if time.monotonic() > deadline:
            zero_stale = 0 in detector.stale([0])
            raise RuntimeError(
                "elastic: no verdict for chunk "
                f"{chunk} (epoch {epoch}) within "
                f"{cfg.verdict_timeout_s}s"
                + (" — rank 0 (the KV coordinator) is heartbeat-stale; "
                   "losing the coordinator is not survivable in-memory "
                   "(cold fallback: checkpoint_dir)" if zero_stale
                   else ""))
        time.sleep(cfg.poll_interval_s)


def remesh_barrier(kv, cfg: ElasticConfig, epoch: int, rank: int,
                   survivors: Sequence[int]) -> None:
    """KV-polling barrier among the survivors before the new epoch's
    first collective (so nobody enters the fresh gloo rendezvous while
    a peer is still rebuilding its arrays)."""
    prefix = _ready_prefix(cfg.namespace, epoch)
    kv.set(prefix + str(rank), "1")
    deadline = time.monotonic() + cfg.verdict_timeout_s
    while True:
        present = set()
        for key in kv.list(prefix):
            try:
                present.add(int(key.rsplit("/", 1)[-1]))
            except ValueError:
                continue
        if all(r in present for r in survivors):
            return
        if time.monotonic() > deadline:
            missing = sorted(set(survivors) - present)
            raise RuntimeError(f"elastic: ranks {missing} never reached "
                               f"the epoch-{epoch} re-mesh barrier")
        time.sleep(cfg.poll_interval_s)


# ---------------------------------------------------------------------------
# The elastic driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticRunResult:
    """One `run_mesh_elastic` trajectory plus its recovery accounting."""

    w: np.ndarray
    values: np.ndarray
    nnz: np.ndarray
    comm_bytes_per_round: float
    events: Tuple[dict, ...]          # one per re-mesh (see below)
    epoch: int                        # final mesh epoch (0 = no failure)
    ownership: Ownership              # final worker->rank map
    worker_ids: Tuple[int, ...]       # workers THIS rank ended up owning
    survivors: Tuple[int, ...]
    seconds: float
    process_id: int
    num_processes: int

    @property
    def degraded(self) -> bool:
        return bool(self.events)


def _parse_kill_env() -> Optional[Tuple[int, int]]:
    raw = os.environ.get(KILL_ENV)
    if not raw:
        return None
    rank_s, round_s = raw.split(":")
    return int(rank_s), int(round_s)


def _survivor_mesh(survivors: Sequence[int], axis: str):
    """1-D mesh over the survivors' devices (one device per rank)."""
    import jax
    from jax.sharding import Mesh
    alive = set(survivors)
    devs = [d for d in jax.devices() if d.process_index in alive]
    if len(devs) != len(survivors):
        raise RuntimeError(
            f"elastic needs exactly one device per rank "
            f"({len(survivors)} survivors, {len(devs)} devices)")
    return Mesh(np.asarray(devs), (axis,))


def run_mesh_elastic(obj, reg, data, y, w0, cfg, *,
                     ecfg: Optional[ElasticConfig] = None,
                     axis: str = "workers") -> ElasticRunResult:
    """pSCOPE over a real mesh that SURVIVES losing ranks mid-run.

    Every process of the `jax.distributed` job calls this with the same
    arguments (`data`: a committed `ShardStore`, or worker-major
    `CSRMatrix` + labels for in-memory tests).  The caller must have
    brought the job up with `init_distributed(elastic=True)` — the
    default coordination-service liveness threshold would otherwise
    terminate the survivors ~100 s after a death.

    The trajectory runs in `ecfg.check_every`-round chunks through the
    stacked scanned driver; chunk boundaries are the detection points
    (see the module docstring for the protocol).  On a detected death
    the survivors re-mesh, adopt the orphaned workers' shard extents,
    and resume — the logical worker count p never changes, so the
    returned history matches the uninterrupted p-worker trajectory
    within fp32 (and is bit-identical across the surviving ranks).

    After a degraded run the process MUST exit via `exit_now` (the
    distributed shutdown barrier would wait forever for the dead rank).
    """
    import jax

    from repro.core import pscope
    from repro.launch.mesh import comm_bytes_per_round, stacked_worker_arrays
    from repro.train.elastic import failure_plan, initial_ownership

    ecfg = ecfg or ElasticConfig()
    me = int(jax.process_index())
    nprocs = int(jax.process_count())
    survivors = list(range(nprocs))
    ns = ecfg.namespace

    from repro.datasets.shards import ShardStore
    if isinstance(data, ShardStore):
        p, d = int(data.p), int(data.d)
    else:
        p, d = int(data.vals.shape[0]), int(data.d)
    ownership = initial_ownership(p, nprocs)
    cfg = dataclasses.replace(cfg, inner_path="lazy")

    kv = DistributedKV() if nprocs > 1 else LocalKV()
    hb = Heartbeat(kv, ns, me, ecfg.heartbeat_interval_s)
    hb.beat_once()
    hb.start()
    detector = FailureDetector(kv, ns, survivors,
                               ecfg.heartbeat_timeout_s)
    kill = _parse_kill_env()

    # cold fallback: resume from the newest checkpoint when one exists
    t0_round, w = 0, np.asarray(w0, np.float32)
    if ecfg.checkpoint_dir:
        from repro.train.checkpoint import latest_step, restore_checkpoint
        step = latest_step(ecfg.checkpoint_dir)
        if step is not None:
            tree, meta = restore_checkpoint(ecfg.checkpoint_dir, step)
            w = np.asarray(tree["w"], np.float32)
            t0_round = int(meta["metadata"]["round"])
    ckpt = None
    if ecfg.checkpoint_dir and ecfg.checkpoint_every > 0 and me == 0:
        from repro.train.checkpoint import AsyncCheckpointer
        ckpt = AsyncCheckpointer(ecfg.checkpoint_dir)

    mesh = _survivor_mesh(survivors, axis)
    arrays = stacked_worker_arrays(mesh, axis, ownership, data, y)

    T = cfg.outer_steps
    epoch = 0
    chunk = 0
    t = t0_round
    values: List[float] = []
    nnzs: List[int] = []
    events: List[dict] = []
    wall0 = time.perf_counter()

    while t < T:
        chunk_len = min(ecfg.check_every, T - t)
        seg_cfg = dataclasses.replace(cfg, outer_steps=chunk_len)
        vals_g, cols_g, y_g, slots_g, p_total = arrays
        status, w_new, seg_vals, seg_nnz = "ok", None, None, None
        try:
            w_new, seg_vals, seg_nnz = pscope.run_stacked_scanned(
                obj, reg, vals_g, cols_g, y_g, slots_g, w, seg_cfg, mesh,
                axis=axis, start_round=t, p_total=p_total)
        except Exception as e:       # noqa: BLE001 — a peer died mid-
            status = f"failed: {e}"  # collective; report, roll back
            print(f"elastic: rank {me} chunk {chunk} (rounds {t}.."
                  f"{t + chunk_len}) compute failed: {e!r}",
                  file=sys.stderr, flush=True)
        if kill is not None and kill[0] == me and t < kill[1] <= t + chunk_len:
            # die AFTER the chunk's collectives, BEFORE the marker: the
            # survivors detect the silence at the barrier, never inside
            # a psum.  SIGKILL — no atexit, no shutdown barrier.
            sys.stdout.flush()
            sys.stderr.flush()
            os.kill(os.getpid(), signal.SIGKILL)

        if nprocs == 1:
            verdict = {"op": "continue", "resume_round": t + chunk_len,
                       "dead": []}
            if status != "ok":
                raise RuntimeError(f"elastic single-process chunk failed: "
                                   f"{status}")
        else:
            publish_marker(kv, ns, epoch, chunk, me,
                           "ok" if status == "ok" else "failed",
                           t + chunk_len)
            if me == survivors[0]:
                verdict = leader_verdict(kv, ecfg, epoch, chunk, survivors,
                                         detector, t, t + chunk_len)
            else:
                verdict = follower_verdict(kv, ecfg, epoch, chunk, detector)

        if verdict["op"] == "continue":
            if not values:
                values.append(float(seg_vals[0]))
                nnzs.append(int(seg_nnz[0]))
            values.extend(float(v) for v in seg_vals[1:])
            nnzs.extend(int(x) for x in seg_nnz[1:])
            w, t = w_new, t + chunk_len
            chunk += 1
            if ckpt is not None and chunk % ecfg.checkpoint_every == 0:
                ckpt.save(t, {"w": np.asarray(w)},
                          metadata={"round": int(t), "epoch": int(epoch)})
            continue

        # --- re-mesh ------------------------------------------------------
        dead = list(verdict["dead"])
        resume = int(verdict["resume_round"])
        if 0 in dead:
            raise RuntimeError("elastic: rank 0 (the KV coordinator) "
                               "died — not survivable in-memory")
        t_remesh = time.perf_counter()
        survivors = [r for r in survivors if r not in dead]
        ownership = failure_plan(ownership, dead)
        epoch += 1
        mesh = _survivor_mesh(survivors, axis)
        arrays = stacked_worker_arrays(mesh, axis, ownership, data, y)
        remesh_barrier(kv, ecfg, epoch, me, survivors)
        remesh_s = time.perf_counter() - t_remesh
        if resume == t + chunk_len and status == "ok":
            if not values:
                values.append(float(seg_vals[0]))
                nnzs.append(int(seg_nnz[0]))
            values.extend(float(v) for v in seg_vals[1:])
            nnzs.extend(int(x) for x in seg_nnz[1:])
            w = w_new
        # else: keep the chunk-start iterate (rollback; history untouched)
        events.append({
            "round": int(t + chunk_len), "resume_round": resume,
            "rounds_to_recover": int(t + chunk_len - resume),
            "dead": dead, "epoch": int(epoch),
            "remesh_seconds": float(remesh_s),
            "survivors": list(survivors),
            "ownership": {int(r): list(ws)
                          for r, ws in ownership.items()},
        })
        t = resume
        chunk += 1

    hb.stop()
    if ckpt is not None:
        ckpt.wait()
    return ElasticRunResult(
        w=np.asarray(w), values=np.asarray(values, np.float64),
        nnz=np.asarray(nnzs, np.int64),
        comm_bytes_per_round=comm_bytes_per_round(d),
        events=tuple(events), epoch=epoch,
        ownership=dict(ownership),
        worker_ids=tuple(ownership.get(me, ())),
        survivors=tuple(survivors),
        seconds=time.perf_counter() - wall0,
        process_id=me, num_processes=nprocs)


def exit_now(code: int = 0) -> None:
    """Flush and `os._exit` — the ONLY safe way to leave a degraded
    process: normal interpreter exit runs the `jax.distributed`
    shutdown barrier, which waits forever for the dead rank."""
    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(code)
