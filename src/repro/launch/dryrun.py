import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without
hardware: jit(step).lower(**input_specs).compile() must succeed on the
production mesh, memory_analysis() must fit 16 GiB/chip, and
cost_analysis() + the parsed collective schedule feed the roofline
table (EXPERIMENTS.md section Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k \
      --mesh multi --step pscope --out results/dryrun/x.json
  python -m repro.launch.dryrun --all --mesh both   # full grid, resumable
"""
import argparse
import json
import sys
import time
import traceback

import numpy as np


def _build_step(arch: str, shape_name: str, mesh, step_kind: str,
                overrides=None):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro import configs
    from repro.configs.base import SHAPES, cell_applicable
    from repro.models import build_model
    from repro.sharding import rules_for_config
    from repro.optim.pscope_dl import (PScopeDLConfig, make_pscope_train_step,
                                       make_standard_train_step,
                                       init_train_state)
    from repro.optim import optimizers as opt
    from repro.models import module as mod

    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": True, "reason": why}

    multi_pod = "pod" in mesh.axis_names
    # parallelism mode: TP-only keeps params replicated over DP — fine
    # for inference of small archs; training holds optimizer state
    # (AdamW moments, or pSCOPE's u/z/anchor), so anything above ~2B
    # params uses FSDP+TP (ZeRO-3 over the `data` axis).
    big_infer = arch in ("qwen3-moe-30b-a3b", "qwen3-moe-235b-a22b",
                         "phi3-medium-14b", "llama-3.2-vision-11b")
    big_train = big_infer or arch in ("minitron-4b", "minicpm-2b",
                                      "zamba2-2.7b")
    if shape.kind == "train":
        mode = "fsdp_tp" if big_train else "tp"
    else:
        mode = "fsdp_tp" if big_infer else "tp"
    if step_kind == "pscope" and multi_pod and cfg.d_model >= 1024:
        mode = "fsdp_tp"
    if step_kind == "pscope" and not multi_pod and big_train:
        return None, {"skipped": True,
                      "reason": "single-pod pSCOPE needs TP-replicated "
                                "params (workers own the data axis); this "
                                "arch requires FSDP — covered by the "
                                "multi-pod cell"}
    if overrides and "mode" in overrides:
        mode = overrides["mode"]
    tp_size = mesh.shape["model"]
    # activation sequence parallelism for full-sequence cells: the
    # residual stream is seq-sharded over `model` between blocks, so
    # the per-layer stored activations shrink by the TP degree
    seq_parallel = shape.kind in ("train", "prefill")
    if overrides and "seq_parallel" in overrides:
        seq_parallel = overrides["seq_parallel"]
    rules = rules_for_config(cfg, mode, multi_pod, tp_size,
                             seq_parallel=seq_parallel)

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    # tiny global batches (long_500k has B=1) cannot shard the DP axes
    if shape.global_batch % dp_size != 0:
        rules["batch"] = None
        dp = ()
    if overrides and "rules" in overrides:
        rules.update(overrides["rules"])
    model = build_model(cfg, rules)
    pspecs = model.param_pspecs()
    params_abs = model.abstract_params()

    def in_shard(tree_specs):
        import jax
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree_specs)

    if shape.kind == "train":
        batch_specs = model.input_specs(shape)
        batch_shardings = {k: NamedSharding(mesh, P(dp))
                           for k in batch_specs}
        if step_kind == "pscope":
            from repro.optim.pscope_dl import make_pscope_train_step_stacked
            waxes = ("pod",) if multi_pod else ("data",)
            # single-pod pSCOPE needs TP-replicated params (workers own
            # the data axis); multi-pod keeps FSDP over data
            pcfg = PScopeDLConfig(
                inner_steps=(overrides or {}).get("inner_steps", 2),
                num_microbatches=(overrides or {}).get("n_mb", 2),
                lam1=1e-5, lam2=1e-6, worker_axes=waxes,
                # z in bf16: the anchor gradient is already averaged
                # over the full batch (low variance); halves pSCOPE's
                # extra state (u + z + anchor w)
                z_dtype=jnp.bfloat16,
                unroll_loops=(overrides or {}).get("unroll", False))
            # the stacked-worker formulation (pure auto-SPMD) is robust
            # across FSDP/TP modes; the manual shard_map variant trips
            # several XLA partitioner bugs on this version (see
            # optim/pscope_dl.py docstrings) and remains a library
            # option exercised by the distributed tests on small meshes
            step = make_pscope_train_step_stacked(model, mesh, pcfg,
                                                  donate=False)
            state_abs = jax.eval_shape(
                lambda p: init_train_state(p, pcfg), params_abs)
            key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = jax.jit(
                step.__wrapped__,
                in_shardings=(in_shard(pspecs),
                              jax.tree_util.tree_map(
                                  lambda _: NamedSharding(mesh, P()),
                                  state_abs),
                              batch_shardings, NamedSharding(mesh, P())),
            ).lower(params_abs, state_abs, batch_specs, key_abs)
        else:
            n_mb = (overrides or {}).get("n_mb", 4)
            step = make_standard_train_step(model, mesh,
                                            num_microbatches=n_mb,
                                            moment_dtype=(
                                                jnp.bfloat16 if "235b" in arch
                                                else jnp.float32),
                                            donate=False)
            opt_abs = jax.eval_shape(
                lambda p: opt.adamw_init(
                    p, jnp.bfloat16 if "235b" in arch else jnp.float32),
                params_abs)
            opt_shardings = jax.tree_util.tree_map(
                lambda _: None, opt_abs)
            # moments shard like params (ZeRO-1/3 consistent)
            opt_shardings = {
                "m": in_shard(pspecs), "v": in_shard(pspecs),
                "t": NamedSharding(mesh, P())}
            key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
            lowered = jax.jit(
                step.__wrapped__,
                in_shardings=(in_shard(pspecs), opt_shardings,
                              batch_shardings, NamedSharding(mesh, P())),
            ).lower(params_abs, opt_abs, batch_specs, key_abs)
        return lowered, {"kind": "train", "step": step_kind,
                         "mode": mode, "params": model.param_count()}

    if shape.kind == "prefill":
        batch_specs = model.input_specs(shape)
        batch_shardings = {k: NamedSharding(mesh, P(dp))
                           for k in batch_specs}

        def prefill(params, batch):
            return model.logits(params, batch)

        lowered = jax.jit(
            prefill,
            in_shardings=(in_shard(pspecs), batch_shardings),
            out_shardings=NamedSharding(mesh, P(dp, None, "model")),
        ).lower(params_abs, batch_specs)
        return lowered, {"kind": "prefill", "mode": mode,
                         "params": model.param_count()}

    # decode
    B, S = shape.global_batch, shape.seq_len
    cache_specs = model.cache_specs(B, S)
    cache_abs = mod.abstract_params(cache_specs)
    cache_shardings = in_shard(mod.params_pspecs(cache_specs, rules))
    in_specs = model.input_specs(shape)

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    lowered = jax.jit(
        serve_step,
        in_shardings=(in_shard(pspecs), cache_shardings,
                      NamedSharding(mesh, P(dp)), NamedSharding(mesh, P(dp))),
        # the KV cache is donated: decode updates it in place (input/
        # output aliasing), halving the serving working set
        donate_argnums=(1,),
    ).lower(params_abs, cache_abs, in_specs["tokens"], in_specs["pos"])
    return lowered, {"kind": "decode", "mode": mode,
                     "params": model.param_count()}


def run_cell(arch: str, shape_name: str, mesh_kind: str, step_kind: str,
             out_path: str = None, overrides=None) -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh, HBM_BYTES
    from repro.launch import roofline as rf
    from repro.configs.base import SHAPES
    from repro import configs

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "step": step_kind, "devices": int(np.prod(mesh.devices.shape))}
    try:
        with mesh:
            lowered, meta = _build_step(arch, shape_name, mesh, step_kind,
                                        overrides)
            result.update(meta)
            if lowered is None:
                result["status"] = "skipped"
                return result
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        chips_per_pod = 256
        costs = rf.analyze_hlo(hlo, chips_per_pod)
        terms = rf.roofline_terms(costs)
        cfg = configs.get(arch)
        shape = SHAPES[shape_name]
        mf = rf.model_flops(cfg, shape, backward=(meta["kind"] == "train"))
        mf_per_chip = mf / result["devices"]
        if meta.get("step") == "pscope":
            # pscope computes 1 z-pass + 2 grads per inner step
            ov = overrides or {}
            mf_per_chip *= (1 + 2 * ov.get("inner_steps", 2)
                            / ov.get("n_mb", 2))
        result.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "per_device_bytes": {
                "argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "peak_est": mem.argument_size_in_bytes
                + mem.temp_size_in_bytes,
            },
            "fits_hbm": (mem.argument_size_in_bytes
                         + mem.temp_size_in_bytes) < HBM_BYTES,
            "xla_cost_analysis": {"flops_body_once": float(
                cost.get("flops", 0.0)), "bytes_body_once": float(
                cost.get("bytes accessed", 0.0))},
            "flops_per_chip": costs.flops,
            "bytes_per_chip": costs.bytes,
            "collectives": {
                "intra_bytes_per_chip": costs.coll_intra,
                "cross_pod_bytes_per_chip": costs.coll_cross,
                "op_counts": costs.op_counts,
                "op_bytes": costs.op_bytes,
            },
            "roofline": terms,
            "model_flops_per_chip": mf_per_chip,
            "useful_ratio": (mf_per_chip / costs.flops) if costs.flops
            else None,
        })
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-3000:]
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--step", default="standard",
                    choices=["standard", "pscope", "serve"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--n-mb", type=int, default=None)
    ap.add_argument("--no-seq-parallel", action="store_true")
    args = ap.parse_args()

    overrides = {}
    if args.n_mb:
        overrides["n_mb"] = args.n_mb
    if args.no_seq_parallel:
        overrides["seq_parallel"] = False
    res = run_cell(args.arch, args.shape, args.mesh, args.step, args.out,
                   overrides=overrides or None)
    keep = {k: v for k, v in res.items() if k not in ("traceback",)}
    print(json.dumps(keep, indent=2, default=str))
    if res["status"] == "error":
        print(res.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
