#!/usr/bin/env python
"""Training launcher.

    python -m repro.launch.train --arch qwen2-1.5b --steps 100 \
        [--reduced] [--optimizer pscope|adamw] [--ckpt-dir DIR]

On real hardware this process runs once per host (jax.distributed);
on this container it drives the same code path on local devices.
Resumable: re-running continues from the newest checkpoint.
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import TokenDataset
from repro.models import build_model
from repro.optim import optimizers as opt
from repro.optim.pscope_dl import (PScopeDLConfig, make_pscope_train_step,
                                   make_standard_train_step,
                                   init_train_state)
from repro.sharding import make_rules
from repro.train.train_loop import run_training, LoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (full configs need a TPU pod)")
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--optimizer", default="pscope",
                    choices=["pscope", "adamw"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--inner-steps", type=int, default=4)
    ap.add_argument("--n-mb", type=int, default=2)
    ap.add_argument("--lam1", type=float, default=1e-6)
    ap.add_argument("--lam2", type=float, default=1e-7)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=args.reduced)
    rules = make_rules("tp", multi_pod=False)
    model = build_model(cfg, rules)
    print(f"{args.arch} ({'reduced' if args.reduced else 'full'}): "
          f"{model.param_count():,} params, optimizer={args.optimizer}")

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    ds = TokenDataset(vocab_size=cfg.vocab_size, seed=0)
    key = jax.random.PRNGKey(0)

    if args.optimizer == "pscope":
        pcfg = PScopeDLConfig(eta=args.lr, inner_steps=args.inner_steps,
                              num_microbatches=args.n_mb, lam1=args.lam1,
                              lam2=args.lam2, worker_axes=("data",))
        step = make_pscope_train_step(model, mesh, pcfg, donate=False)

        def init_state():
            params = model.init(jax.random.PRNGKey(0))
            return {"params": params, "opt": init_train_state(params, pcfg)}

        def train_step(state, batch, i):
            with mesh:
                p, o, m = step(state["params"], state["opt"], batch, key)
            return {"params": p, "opt": o}, m
    else:
        step = make_standard_train_step(model, mesh,
                                        num_microbatches=args.n_mb,
                                        lr=args.lr, donate=False)

        def init_state():
            params = model.init(jax.random.PRNGKey(0))
            return {"params": params, "opt": opt.adamw_init(params)}

        def train_step(state, batch, i):
            with mesh:
                p, o, m = step(state["params"], state["opt"], batch, key)
            return {"params": p, "opt": o}, m

    def batch_fn(i):
        toks, labels = ds.batch(i, args.batch, args.seq)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    loop = LoopConfig(total_steps=args.steps,
                      checkpoint_every=args.ckpt_every,
                      checkpoint_dir=args.ckpt_dir,
                      log_path=args.ckpt_dir + "/metrics.jsonl")
    run_training(train_step, init_state, batch_fn, loop)
    print("done ->", args.ckpt_dir)


if __name__ == "__main__":
    main()
