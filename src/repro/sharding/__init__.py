from repro.sharding.logical import (LOGICAL_RULES, SOLVER_LOGICAL_AXES,
                                    make_rules, batch_axes, dp_axis_names,
                                    rules_for_config, solver_rules)

__all__ = ["LOGICAL_RULES", "SOLVER_LOGICAL_AXES", "make_rules",
           "batch_axes", "dp_axis_names", "rules_for_config", "solver_rules"]
