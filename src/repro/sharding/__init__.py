from repro.sharding.logical import (LOGICAL_RULES, make_rules, batch_axes,
                                    dp_axis_names, rules_for_config)

__all__ = ["LOGICAL_RULES", "make_rules", "batch_axes", "dp_axis_names",
           "rules_for_config"]
