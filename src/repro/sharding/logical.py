"""Logical sharding axes -> mesh axes.

Parallelism modes:
  tp       — tensor-parallel only; params replicated over DP axes.
             Right for <= ~5B params (fits HBM replicated).
  fsdp_tp  — ZeRO-3: the `embed` dim of every large weight is sharded
             over the `data` axis in addition to TP over `model`.
             Mandatory for the 30B/235B MoE configs on 16 GB chips.

Logical axes used by the model zoo:
  layers     scan dimension (never sharded)
  embed      d_model dim of weights — FSDP target
  heads/mlp/vocab/expert  TP targets (over `model`)
  kv_heads   KV heads; left unsharded (GQA kv count < model size)
  head_dim/state/conv/frames  never sharded
  batch      DP axes for activations
  seq        activation sequence dim (sharded over `model` for
             long-context decode KV via `kv_seq`)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

DP_AXES = ("pod", "data")


def make_rules(mode: str = "fsdp_tp", multi_pod: bool = True,
               shard_kv_seq: bool = True) -> Dict[Optional[str], Any]:
    dp: Any = DP_AXES if multi_pod else "data"
    rules: Dict[Optional[str], Any] = {
        None: None,
        "layers": None,
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "state": None,
        "conv": None,
        "frames": None,
        # activations
        "batch": dp,
        "seq": None,
        "attn_seq": None,       # "model" = sequence-parallel attention
        # residual stream between blocks: "model" = Megatron-style
        # activation sequence parallelism (norms/residuals run seq-
        # sharded; XLA inserts the all-gather at the first TP matmul and
        # the reduce-scatter after the block) — cuts the per-layer saved
        # activations by the TP degree
        "res_seq": None,
        "kv_seq": "model" if shard_kv_seq else None,
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_expert": "model",
        # MoE dispatch buffers (E, C, d): capacity slots sharded over
        # `data` so the buffers scale with the DP degree
        "moe_cap": "data",
    }
    if mode == "fsdp_tp":
        rules["embed"] = "data"
    elif mode == "tp":
        pass
    else:
        raise ValueError(f"unknown sharding mode {mode!r}")
    return rules


def rules_for_config(cfg, mode: str, multi_pod: bool, tp_size: int = 16,
                     seq_parallel: bool = False
                     ) -> Dict[Optional[str], Any]:
    """Per-arch rules: archs whose head count does not divide the model
    axis fall back from head-TP to sequence-parallel attention (weights
    replicated over `model`, the seq dim of q/k/v sharded instead — XLA
    all-gathers the small GQA KV per block)."""
    rules = make_rules(mode, multi_pod=multi_pod)
    if seq_parallel:
        rules["res_seq"] = "model"
    heads_ok = cfg.num_heads % tp_size == 0
    if not heads_ok:
        rules["heads"] = None
        rules["act_heads"] = None
        rules["attn_seq"] = "model"
    if cfg.family in ("ssm", "hybrid"):
        # rwkv/mamba heads (d_inner/head_dim) always divide here; keep
        # head-TP for the recurrent mixers even when the shared attn
        # block fell back to SP (zamba2: 32 attn heads % 16 == 0 anyway)
        pass
    return rules


LOGICAL_RULES = make_rules()


def batch_axes(multi_pod: bool = True):
    return DP_AXES if multi_pod else ("data",)


def dp_axis_names(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in DP_AXES)


# ---------------------------------------------------------------------------
# Solver-side logical axes (the CALL sparse-learning stack)
# ---------------------------------------------------------------------------
# The pSCOPE data model has exactly two logical dimensions worth naming:
#   workers    the partition axis pi = {D_1..D_p}: shard rows, labels,
#              statics — everything that lives on one worker and never
#              crosses the wire during inner loops
#   features   the d coordinate axis of the iterate w / gradient z.
#              Unsharded today (w is replicated; the two per-round
#              collectives move O(d) bytes); a mesh axis here is the
#              future model-parallel direction, which MeshSpec already
#              expresses declaratively.
# `launch.mesh.MeshSpec` maps these onto device-mesh axes; keeping the
# table here (with the model zoo's rules) preserves the repo's single
# layout/mesh-shape separation point.

SOLVER_LOGICAL_AXES = ("workers", "features")


def solver_rules(workers_axis: str = "workers",
                 features_axis: Optional[str] = None
                 ) -> Dict[Optional[str], Any]:
    """Logical->mesh layout for the CALL solver arrays."""
    return {None: None, "workers": workers_axis, "features": features_axis}
