"""Table 2 analogue: wall time to 1e-3 suboptimality, pSCOPE vs DBCD."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (build_problem, reference_optimum,
                               time_to_suboptimality)
from repro.core import PScopeConfig, run
from repro.core.baselines import dbcd_history
from repro.core.partition import uniform_partition, stack_partition


def main() -> List[Dict]:
    rows = []
    for ds in ("cov", "rcv1"):
        for model in ("logistic", "lasso"):
            X, y, obj, reg = build_problem(ds, model, scale=0.05)
            n, d = X.shape
            p_star = reference_optimum(obj, reg, X, y)
            idx = uniform_partition(jax.random.PRNGKey(0), n, 8)
            Xp, yp = stack_partition(X, y, idx)
            w0 = jnp.zeros(d)
            n_k = Xp.shape[1]

            cfg = PScopeConfig(eta=1.2, inner_steps=3 * n_k, inner_batch=1,
                               outer_steps=16)
            t0 = time.perf_counter()
            _, h = run(obj, reg, Xp, yp, w0, cfg)
            per = (time.perf_counter() - t0) / 16
            tts_ps = time_to_suboptimality(
                h, [per * i for i in range(len(h))], p_star)

            t0 = time.perf_counter()
            _, h2 = dbcd_history(obj, reg, X, y, w0, p=8, outer_steps=150)
            per2 = (time.perf_counter() - t0) / 150
            tts_db = time_to_suboptimality(
                h2, [per2 * i for i in range(len(h2))], p_star)

            ratio = (tts_db / tts_ps if np.isfinite(tts_db)
                     and np.isfinite(tts_ps) and tts_ps > 0 else float("inf"))
            rows.append({
                "name": f"table2/{ds}/{model}",
                "us_per_call": f"{per * 1e6:.0f}",
                "derived": (f"pscope_tts={tts_ps:.3g};dbcd_tts="
                            f"{tts_db:.3g};speedup={ratio:.3g}"),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
