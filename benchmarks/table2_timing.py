"""Table 2 analogue: wall time to 1e-3 suboptimality, pSCOPE vs DBCD.

Both solvers run through the `core.solvers` registry; time-to-eps comes
straight from the Trace's streaming wall clock (no post-hoc per-round
averaging).  Every problem is split 80/20 train/test
(`datasets.train_test_split`): solvers train on the train partition and
the rows report held-out objective/accuracy of the final iterate via
the `Trace.heldout` hook — pSCOPE's lands through the zero-sync
post-hoc feed (`SolverConfig.extras["eval"]`), DBCD's is evaluated
post-hoc here.

``--dataset NAME`` (via benchmarks.run) swaps the in-memory synthetic
problem for a `repro.datasets` registry dataset: real LIBSVM text
ingested through the mmap shard store.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import build_problem, reference_optimum
from repro.core import solvers
from repro.core.solvers import SolverConfig, evaluate_heldout
from repro.datasets.split import train_test_split
from repro.partition import build_partition

EPS = 1e-3
TEST_FRAC = 0.2


def _split_problem(ds: str, model: str, p: int, scale: float):
    """(obj, reg, train Partition, (X_test, y_test), p_star) — in-memory."""
    X, y, obj, reg = build_problem(ds, model, scale=scale)
    Xtr, ytr, Xte, yte = train_test_split(np.asarray(X), y,
                                          test_frac=TEST_FRAC, seed=0)
    part = _rect_uniform_partition(Xtr, ytr, p)
    p_star = reference_optimum(obj, reg, part.X, part.y)
    return obj, reg, part, (Xte, yte), p_star


def _rect_uniform_partition(Xtr, ytr, p: int):
    """Uniform train partition over a rectangular n_k * p row subset.

    Truncating BEFORE partitioning makes the flat view (DBCD, the
    FISTA reference) and the worker-major view (pSCOPE) range over
    exactly the same instances, so p_star, gap and tts compare like
    against like."""
    from repro.datasets.split import take_rows
    n_rect = (len(ytr) // p) * p
    return build_partition("uniform",
                           take_rows(Xtr, np.arange(n_rect)),
                           ytr[:n_rect], p)


def _split_registry_problem(name: str, p: int, scale: float):
    """Same contract, but through the LIBSVM -> mmap shard store path."""
    from benchmarks.common import build_registry_problem
    obj, reg, full_part = build_registry_problem(name, p=p, scale=scale)
    Xtr, ytr, Xte, yte = train_test_split(full_part.csr,
                                          np.asarray(full_part.y),
                                          test_frac=TEST_FRAC, seed=0)
    part = _rect_uniform_partition(Xtr, ytr, p)
    p_star = reference_optimum(obj, reg, part.X, part.y)
    return obj, reg, part, (Xte, yte), p_star


def _row(ds: str, model: str, obj, reg, part, eval_data, p_star) -> Dict:
    tr_ps = solvers.run("pscope", obj, reg, part,
                        SolverConfig(rounds=16, eta=1.2, inner_epochs=3.0,
                                     extras={"eval": eval_data}))
    tr_db = solvers.run("dbcd", obj, reg, part, SolverConfig(rounds=150))
    tr_db.record_heldout(
        **evaluate_heldout(obj, reg, *eval_data, tr_db.w_final))

    tts_ps = tr_ps.time_to(p_star, EPS)
    tts_db = tr_db.time_to(p_star, EPS)
    ratio = (tts_db / tts_ps if np.isfinite(tts_db)
             and np.isfinite(tts_ps) and tts_ps > 0 else float("inf"))
    ho = "".join(f";heldout_{k}={v:.4g}"
                 for k, v in sorted(tr_ps.heldout.items()))
    ho += "".join(f";dbcd_heldout_{k}={v:.4g}"
                  for k, v in sorted(tr_db.heldout.items()))
    return {
        "name": f"table2/{ds}/{model}",
        "us_per_call":
            f"{tr_ps.seconds[-1] / max(tr_ps.rounds, 1) * 1e6:.0f}",
        "derived": (f"pscope_tts={tts_ps:.3g};dbcd_tts="
                    f"{tts_db:.3g};speedup={ratio:.3g}{ho}"),
    }


def main(dataset: str = None) -> List[Dict]:
    if dataset is not None:
        from repro import datasets as registry
        return [_row(dataset, registry.get(dataset).model,
                     *_split_registry_problem(dataset, p=8, scale=0.05))]
    rows = []
    for ds in ("cov", "rcv1"):
        for model in ("logistic", "lasso"):
            rows.append(_row(ds, model,
                             *_split_problem(ds, model, p=8, scale=0.05)))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
