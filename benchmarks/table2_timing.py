"""Table 2 analogue: wall time to 1e-3 suboptimality, pSCOPE vs DBCD.

Both solvers run through the `core.solvers` registry; time-to-eps comes
straight from the Trace's streaming wall clock (no post-hoc per-round
averaging).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import build_partitioned_problem, reference_optimum
from repro.core import solvers
from repro.core.solvers import SolverConfig

EPS = 1e-3


def main() -> List[Dict]:
    rows = []
    for ds in ("cov", "rcv1"):
        for model in ("logistic", "lasso"):
            obj, reg, part = build_partitioned_problem(ds, model, p=8,
                                                       scale=0.05)
            p_star = reference_optimum(obj, reg, part.X, part.y)

            tr_ps = solvers.run("pscope", obj, reg, part,
                                SolverConfig(rounds=16, eta=1.2,
                                             inner_epochs=3.0))
            tr_db = solvers.run("dbcd", obj, reg, part,
                                SolverConfig(rounds=150))

            tts_ps = tr_ps.time_to(p_star, EPS)
            tts_db = tr_db.time_to(p_star, EPS)
            ratio = (tts_db / tts_ps if np.isfinite(tts_db)
                     and np.isfinite(tts_ps) and tts_ps > 0 else float("inf"))
            rows.append({
                "name": f"table2/{ds}/{model}",
                "us_per_call":
                    f"{tr_ps.seconds[-1] / max(tr_ps.rounds, 1) * 1e6:.0f}",
                "derived": (f"pscope_tts={tts_ps:.3g};dbcd_tts="
                            f"{tts_db:.3g};speedup={ratio:.3g}"),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
