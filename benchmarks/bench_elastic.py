"""Elastic-recovery benchmark: the BENCH_elastic.json perf trail.

Measures what a mid-run host failure actually costs under
`launch.elastic.run_mesh_elastic` by driving the REAL multi-process
CLI twice on the demo fixture — once clean, once with rank 2 SIGKILLed
mid-run — and comparing end-to-end wall time:

    elastic/clean_wall/p3_r6      3-rank elastic run, no failure
                                  (the chunking + KV-barrier overhead
                                  baseline)
    elastic/degraded_wall/...     same run with one rank killed: wall
                                  time including detection, re-mesh,
                                  and orphan-shard adoption
    elastic/remesh/p3             the re-mesh latency itself (from the
                                  survivors' recovery event), with
                                  rounds_to_recover in `derived`
    elastic/coordinator_loss_wall/...
                                  rank 0 — the KV coordinator — killed
                                  under `--chaos kill-coordinator@K`:
                                  file control plane + external service
                                  host, a survivor fences itself in as
                                  the new verdict issuer
    elastic/rejoin_wall/...       kill-then-rejoin schedule: the
                                  revived rank is re-admitted at a
                                  chunk boundary (W -> W+1, no restart)
    elastic/remesh_overlap/p3     seconds of orphan-shard host-block
                                  build hidden behind the re-mesh
                                  barrier by the background builder

All runs go through `python -m repro.launch.multihost --spawn` in a
child process (jax pins the backend at first use, so the sweep cannot
run in-process under `benchmarks.run`); the degraded run's `--verify`
asserts the recovered trajectory still matches `run_scanned` — the
benchmark doubles as an acceptance check.

    PYTHONPATH=src python -m benchmarks.bench_elastic
    PYTHONPATH=src python -m benchmarks.run --only elastic --json
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import tempfile
import time
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_RANKS = 3
_ROUNDS = 6
_CHECK_EVERY = 2
_VICTIM = 2
_KILL_AT = 3

_ELASTIC_RE = re.compile(
    r"ELASTIC OK: rank (\d+) killed at round (\d+), (\d+) survivors "
    r"re-meshed in ([0-9.]+)s, resumed at round (\d+)")


def _spawn_cli(workdir: str, *extra: str) -> tuple[float, str]:
    """Run the multihost CLI, return (wall seconds, stdout)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    argv = [sys.executable, "-m", "repro.launch.multihost",
            "--spawn", str(_RANKS), "--demo", "--elastic",
            "--rounds", str(_ROUNDS), "--check-every", str(_CHECK_EVERY),
            "--workdir", workdir, *extra]
    t0 = time.monotonic()
    proc = subprocess.run(argv, env=env, capture_output=True, text=True,
                          timeout=600)
    wall = time.monotonic() - t0
    if proc.returncode != 0:
        raise RuntimeError(f"{' '.join(argv)} exited {proc.returncode}:\n"
                           f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    return wall, proc.stdout


def _rank_payloads(spawn_out: str) -> Dict[int, Dict]:
    """The per-rank RESULT payloads echoed through the spawner."""
    payloads: Dict[int, Dict] = {}
    for line in spawn_out.splitlines():
        if line.startswith("RESULT "):
            p = json.loads(line[len("RESULT "):])
            payloads[p["process_id"]] = p
    return payloads


def main(full: bool = False) -> List[Dict]:
    del full  # one fixture size: the cost being measured is protocol-side
    rows: List[Dict] = []
    base = tempfile.mkdtemp(prefix="bench_elastic_")

    clean_wall, clean_out = _spawn_cli(os.path.join(base, "clean"))
    assert "SPAWN OK" in clean_out, clean_out[-1500:]
    rows.append({
        "name": f"elastic/clean_wall/p{_RANKS}_r{_ROUNDS}",
        "us_per_call": clean_wall * 1e6,
        "derived": f"{_RANKS} ranks, {_ROUNDS} rounds, no failure",
    })

    kill_wall, kill_out = _spawn_cli(
        os.path.join(base, "kill"), "--verify",
        "--kill-rank", str(_VICTIM), "--kill-at-round", str(_KILL_AT))
    m = _ELASTIC_RE.search(kill_out)
    assert m and "VERIFY OK" in kill_out, kill_out[-1500:]
    detect_round, survivors = int(m.group(2)), int(m.group(3))
    remesh_s, resume_round = float(m.group(4)), int(m.group(5))
    rows.append({
        "name": f"elastic/degraded_wall/p{_RANKS}_r{_ROUNDS}"
                f"_kill{_VICTIM}",
        "us_per_call": kill_wall * 1e6,
        "derived": f"rank {_VICTIM} killed; {kill_wall / clean_wall:.2f}x "
                   f"clean wall; recovered trajectory verified",
    })
    rows.append({
        "name": f"elastic/remesh/p{_RANKS}",
        "us_per_call": remesh_s * 1e6,
        "derived": f"{survivors} survivors; detected at round "
                   f"{detect_round}, resumed at {resume_round}, "
                   f"rounds_to_recover={detect_round - resume_round}",
    })

    # coordinator loss: --chaos implies the file control plane and an
    # external service host, so rank 0's death is survivable IN MEMORY
    coord_wall, coord_out = _spawn_cli(
        os.path.join(base, "coord"), "--verify",
        "--chaos", f"kill-coordinator@{_KILL_AT}")
    assert "CHAOS OK" in coord_out and "VERIFY OK" in coord_out, \
        coord_out[-1500:]
    ev = _rank_payloads(coord_out)[1]["events"][0]
    rows.append({
        "name": f"elastic/coordinator_loss_wall/p{_RANKS}_r{_ROUNDS}",
        "us_per_call": coord_wall * 1e6,
        "derived": f"rank 0 (coordinator) killed at round {_KILL_AT}; "
                   f"survivors {ev['survivors']} promoted a new verdict "
                   f"issuer, rounds_to_recover="
                   f"{ev['rounds_to_recover']}; no checkpoint fallback; "
                   f"verified",
    })

    # kill-then-rejoin: scale back up W -> W+1 mid-run (needs 8 rounds
    # so the re-admission boundary leaves a non-empty suffix)
    rejoin_wall, rejoin_out = _spawn_cli(
        os.path.join(base, "rejoin"), "--verify", "--rounds", "8",
        "--chaos", f"kill:{_VICTIM}@{_KILL_AT},rejoin@{_KILL_AT + 1}")
    assert "REJOIN OK" in rejoin_out and "VERIFY OK" in rejoin_out, \
        rejoin_out[-1500:]
    payloads = _rank_payloads(rejoin_out)
    join_ev = payloads[0]["events"][-1]
    overlap_s = max(p.get("remesh_overlap_saved_s", 0.0)
                    for p in payloads.values())
    rows.append({
        "name": f"elastic/rejoin_wall/p{_RANKS}_r8",
        "us_per_call": rejoin_wall * 1e6,
        "derived": f"rank {_VICTIM} killed at {_KILL_AT}, re-admitted "
                   f"at round {join_ev['resume_round']} owning "
                   f"{join_ev['ownership'][str(_VICTIM)]}; "
                   f"rounds_to_recover={join_ev['rounds_to_recover']}; "
                   f"suffix verified",
    })
    rows.append({
        "name": f"elastic/remesh_overlap/p{_RANKS}",
        "us_per_call": overlap_s * 1e6,
        "derived": "orphan host-block build seconds hidden behind the "
                   "re-mesh barrier (remesh_overlap_saved_s, max over "
                   "ranks)",
    })
    return rows


if __name__ == "__main__":
    for row in main():
        print(f"{row['name']},{row['us_per_call']},{row['derived']}")
