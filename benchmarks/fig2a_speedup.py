"""Figure 2(a) analogue: pSCOPE speedup for p in {1,2,4,8}.

On this single-core container, wall-clock parallel speedup cannot be
observed directly; we report the paper's speedup metric in
computation-normalized form: rounds-to-epsilon x per-round work
(n_k = n/p inner steps each), i.e. total sequential gradient
evaluations, plus measured wall time for reference.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (build_problem, reference_optimum,
                               time_to_suboptimality)
from repro.core import PScopeConfig, run
from repro.core.partition import uniform_partition, stack_partition

EPS = 1e-6


def main() -> List[Dict]:
    rows = []
    X, y, obj, reg = build_problem("cov", "logistic", scale=0.05)
    n, d = X.shape
    p_star = reference_optimum(obj, reg, X, y, iters=6000)
    base_work = None
    for p in (1, 2, 4, 8):
        idx = uniform_partition(jax.random.PRNGKey(0), n, p)
        Xp, yp = stack_partition(X, y, idx)
        n_k = Xp.shape[1]
        cfg = PScopeConfig(eta=0.5, inner_steps=2 * n_k, inner_batch=1,
                           outer_steps=30)
        t0 = time.perf_counter()
        _, hist = run(obj, reg, Xp, yp, jnp.zeros(d), cfg)
        dt = time.perf_counter() - t0
        sub = np.asarray(hist) - p_star
        rounds = int(np.argmax(sub <= EPS)) if np.any(sub <= EPS) else len(sub)
        # critical-path work per worker: rounds x (n_k full grad + 2 M VR)
        work = rounds * (n_k + 2 * cfg.inner_steps)
        if base_work is None:
            base_work = work
        speedup = base_work / work if work else float("inf")
        rows.append({
            "name": f"fig2a/speedup/p{p}",
            "us_per_call": f"{dt / max(rounds,1) * 1e6:.0f}",
            "derived": (f"rounds_to_{EPS:g}={rounds};"
                        f"critical_path_grads={work};speedup={speedup:.2f}"),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
