"""Figure 2(a) analogue: pSCOPE speedup for p in {1,2,4,8}.

On this single-core container, wall-clock parallel speedup cannot be
observed directly; we report the paper's speedup metric in
computation-normalized form: rounds-to-epsilon x per-round work
(n_k = n/p inner steps each), i.e. total sequential gradient
evaluations, plus measured wall time for reference.  pSCOPE runs
through the `core.solvers` registry (`solvers.run("pscope", ...)`).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import build_problem, reference_optimum
from repro.core import solvers
from repro.core.partition import build_partition
from repro.core.solvers import SolverConfig

EPS = 1e-6


def main() -> List[Dict]:
    rows = []
    X, y, obj, reg = build_problem("cov", "logistic", scale=0.05)
    p_star = reference_optimum(obj, reg, X, y, iters=6000)
    base_work = None
    for p in (1, 2, 4, 8):
        part = build_partition("uniform", X, y, p)
        cfg = SolverConfig(rounds=30, eta=0.5, inner_epochs=2.0)
        trace = solvers.run("pscope", obj, reg, part, cfg)
        sub = np.asarray(trace.suboptimality(p_star))
        rounds = int(np.argmax(sub <= EPS)) if np.any(sub <= EPS) else len(sub)
        # critical-path work per worker: rounds x (n_k full grad + 2 M VR)
        inner_steps = int(cfg.inner_epochs * part.n_k)
        work = rounds * (part.n_k + 2 * inner_steps)
        if base_work is None:
            base_work = work
        speedup = base_work / work if work else float("inf")
        rows.append({
            "name": f"fig2a/speedup/p{p}",
            "us_per_call": f"{trace.seconds[-1] / max(rounds, 1) * 1e6:.0f}",
            "derived": (f"rounds_to_{EPS:g}={rounds};"
                        f"critical_path_grads={work};speedup={speedup:.2f};"
                        f"comm_rounds={trace.comm[-1]:g}"),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
