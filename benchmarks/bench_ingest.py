"""Ingest-throughput benchmark: the BENCH_ingest.json perf trail.

Measures the streaming LIBSVM pipeline stage by stage on a registry
fixture (real LIBSVM text, generated offline and cached under
`datasets.data_root()`):

    ingest/parse/<ds>         chunked vectorized parse only
    ingest/parse_hash/<ds>    parse + signed feature hashing
    ingest/shard/<ds>/<pl>    full ingest: parse -> place -> spill ->
                              padded mmap segments (per placement; a
                              `sequential+delta+bf16` leg ingests with
                              the segment codec and reports the ratio)
    ingest/solve/<ds>         pscope_lazy on the mmap shards — proof the
                              parse->hash->shard->solve path is live

`us_per_call` is the stage's wall time; `derived` carries the
ISSUE-mandated throughput numbers (mb_per_s, rows_per_s) plus row/nnz
counts.  ``--smoke`` runs one tiny fixture end-to-end with correctness
assertions (round-trip vs the in-memory generator arrays) — the CI
ingest step.

    PYTHONPATH=src python -m benchmarks.bench_ingest [--smoke|--full]
    PYTHONPATH=src python -m benchmarks.run --only ingest --json
"""
from __future__ import annotations

import shutil
import time
from typing import Dict, List

import numpy as np

from repro import datasets
from repro.datasets.hashing import FeatureHasher
from repro.datasets.libsvm import IngestStats, iter_libsvm_chunks

CHUNK_BYTES = 1 << 20


def _throughput_row(name: str, stats: IngestStats, extra: str = "") -> Dict:
    return {
        "name": name,
        "us_per_call": f"{stats.seconds * 1e6:.0f}",
        "derived": (f"mb_per_s={stats.mb_per_s:.1f};"
                    f"rows_per_s={stats.rows_per_s:.0f};"
                    f"rows={stats.rows};nnz={stats.nnz};"
                    f"chunks={stats.chunks}{extra}"),
    }


def bench_parse(fixture, name: str, hash_dim_log2=None) -> Dict:
    stats = IngestStats()
    hasher = (FeatureHasher(hash_dim_log2) if hash_dim_log2 is not None
              else None)
    t0 = time.perf_counter()
    for chunk in iter_libsvm_chunks(fixture, chunk_bytes=CHUNK_BYTES,
                                    zero_based=False, stats=stats):
        if hasher is not None:
            hasher(chunk.cols, chunk.vals)
    stats.seconds = time.perf_counter() - t0
    stage = "parse_hash" if hasher is not None else "parse"
    return _throughput_row(f"ingest/{stage}/{name}", stats)


def bench_shard(fixture, name: str, placement: str, p: int, d: int,
                codec: str = None) -> Dict:
    tag = f"{placement}+{codec}" if codec else placement
    out = fixture.parent / f"_bench.{name}.{tag}"
    shutil.rmtree(out, ignore_errors=True)
    store = datasets.ingest_libsvm(fixture, out, p, placement=placement,
                                   n_features=d, zero_based=False,
                                   codec=codec, chunk_bytes=CHUNK_BYTES)
    s = store.manifest["stats"]
    stats = IngestStats(rows=s["rows"], nnz=s["nnz"],
                        bytes_read=s["bytes_read"], chunks=s["chunks"],
                        seconds=s["seconds"])
    extra = f";store_mb={store.nbytes / 1e6:.1f};n_k={store.n_k}"
    if codec:
        extra += f";ratio={store.raw_nbytes / store.nbytes:.2f}"
    row = _throughput_row(f"ingest/shard/{name}/{tag}", stats, extra=extra)
    shutil.rmtree(out, ignore_errors=True)
    return row


def bench_solve(name: str, p: int, scale: float, rounds: int = 4) -> Dict:
    from repro.core import solvers
    from repro.core.solvers import SolverConfig
    loaded = datasets.load(name, p=p, scale=scale)
    t0 = time.perf_counter()
    trace = solvers.run("pscope_lazy", loaded.objective, loaded.regularizer,
                        loaded.partition(),
                        SolverConfig(rounds=rounds, eta=0.5,
                                     inner_epochs=2.0))
    dt = time.perf_counter() - t0
    return {
        "name": f"ingest/solve/{name}",
        "us_per_call": f"{dt / max(trace.rounds, 1) * 1e6:.0f}",
        "derived": (f"final_value={trace.final_value:.5f};"
                    f"rounds={trace.rounds};nnz={trace.nnz[-1]};"
                    f"p={p};n_k={loaded.store.n_k}"),
    }


def _smoke_assert(name: str, scale: float, p: int) -> None:
    """Tiny end-to-end correctness gate for the CI ingest step.

    A cached store is fine to assert against (the CI cache key hashes
    the datasets/ sources, and the manifest mismatch check guards the
    arguments), so this step benefits from the fixture cache."""
    from repro.data.sparse import shard_rows
    loaded = datasets.load(name, p=p, scale=scale)
    csr, y, _ = datasets.reference_arrays(name, scale=scale)
    members = np.asarray(loaded.store.members)
    ref = shard_rows(csr, members)
    assert np.array_equal(np.asarray(loaded.store.vals),
                          np.asarray(ref.vals)), "shard vals drifted"
    assert np.array_equal(np.asarray(loaded.store.yp),
                          np.asarray(y)[members]), "shard labels drifted"


def main(full: bool = False, smoke: bool = False) -> List[Dict]:
    p = 8
    if smoke:
        name, scale = "rcv1-like", 0.02
        _smoke_assert(name, scale, p=4)
        grid = [(name, scale, None)]
        placements = ["sequential"]
    else:
        grid = [("rcv1-like", 0.5, None), ("avazu-like", 0.5, 13)]
        if full:
            grid += [("kdd2012-like", 1.0, 14)]
        placements = ["sequential", "row_hash", "gamma"]

    rows = []
    for name, scale, hash_k in grid:
        prof = datasets.get(name)
        fixture = datasets.ensure_fixture(name, scale=scale)
        rows.append(bench_parse(fixture, name))
        if hash_k is not None:
            rows.append(bench_parse(fixture, name, hash_dim_log2=hash_k))
        for pl in placements:
            if pl == "gamma" and prof.d > 8192:
                continue               # O(p*d) per row: fixture-scale only
            rows.append(bench_shard(fixture, name, pl, p, prof.d))
        # codec leg: same ingest, delta+bf16 segments (ratio in derived)
        rows.append(bench_shard(fixture, name, "sequential", p, prof.d,
                                codec="delta+bf16"))
    rows.append(bench_solve(grid[0][0], p=4 if smoke else p,
                            scale=grid[0][1]))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end cell + correctness assertions")
    ap.add_argument("--full", action="store_true",
                    help="include the kdd2012-scale fixture")
    args = ap.parse_args()
    from benchmarks.common import emit
    emit(main(full=args.full, smoke=args.smoke))
