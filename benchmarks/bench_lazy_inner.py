"""Dense vs lazy inner-epoch sweep — the tentpole perf measurement.

One inner epoch = M prox-SVRG steps on a single worker shard.  The
dense engine pays O(M * d) elementwise traffic regardless of data
sparsity; the lazy engine pays O(M * b * nnz) plus one O(d) Lemma-11
catch-up.  The sweep crosses d in {2^14, 2^16, 2^18} with density in
{1%, 0.1%} (the rcv1 -> kdd regime of Table 1) and reports wall-clock
us_per_call plus an analytic bytes-moved model for each path, so the
roofline crossover (see docs/kernels.md) is visible in the CSV.

Rows are named ``inner_loop/{path}/d{d}/rho{density}`` — the names the
``--json`` flag of benchmarks/run.py keys BENCH_inner_loop.json on.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.prox import Regularizer
from repro.core.pscope import _inner_loop, _lazy_inner_loop
from repro.core.svrg import logistic_h_prime
from repro.data.sparse import csr_to_dense, make_csr_classification

M = 64            # inner steps per epoch (the acceptance-criteria setting)
BATCH = 1         # b = 1 reproduces Algorithm 1
N_ROWS = 64       # shard rows; cost is step-count bound, not data bound
REPEATS = 5

SWEEP_D = (1 << 14, 1 << 16, 1 << 18)
SWEEP_DENSITY = (0.01, 0.001)

REG = Regularizer(1e-4, 1e-4)
ETA = 0.3


def _time_fn(fn, *args) -> float:
    """Median wall seconds per call, after a compile+warmup call."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bytes_dense(d: int, nnz: int) -> int:
    """Per-epoch HBM model: each step reads the (d,) X row (dense view of
    the instance), u, w_anchor, z and writes u -> (b + 4) reads + 1
    write of d floats."""
    return M * (BATCH + 4 + 1) * d * 4


def _bytes_lazy(d: int, nnz: int) -> int:
    """Per-epoch model: each step moves ~6 gather/scatter passes over the
    b*nnz touched entries (vals+cols reads, u/z/w gathers, u writes,
    last stamps) plus the final O(d) catch-up (u, z, last reads + u
    write)."""
    per_step = BATCH * nnz * (2 + 6) * 4
    final = 4 * d * 4
    return M * per_step + final


def bench_point(d: int, density: float, seed: int = 0) -> List[Dict]:
    csr, y, _ = make_csr_classification(N_ROWS, d, density=density, seed=seed)
    nnz = csr.max_nnz
    y = jnp.asarray(y)
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(d).astype(np.float32) * 0.05)
    z = jnp.asarray(rng.randn(d).astype(np.float32) * 0.01)
    idx = jnp.asarray(rng.randint(0, N_ROWS, size=(M, BATCH)), jnp.int32)

    X = csr_to_dense(csr)

    dense_fn = jax.jit(lambda u, Xk, yk, ix: _inner_loop(
        None, REG, ETA, u, w, z, Xk, yk, ix, h_prime=logistic_h_prime))
    lazy_fn = jax.jit(lambda u, v, c, yk, ix: _lazy_inner_loop(
        logistic_h_prime, REG, ETA, u, w, z, v, c, yk, ix))

    # correctness guard: a benchmark that drifted from equivalence would
    # be timing two different algorithms
    u_d = dense_fn(w, X, y, idx)
    u_l = lazy_fn(w, csr.vals, csr.cols, y, idx)
    err = float(jnp.max(jnp.abs(u_d - u_l)))
    assert err < 1e-4, f"lazy/dense diverged at d={d}: {err}"

    t_dense = _time_fn(dense_fn, w, X, y, idx)
    t_lazy = _time_fn(lazy_fn, w, csr.vals, csr.cols, y, idx)
    speedup = t_dense / max(t_lazy, 1e-12)

    tag = f"d{d}/rho{density:g}"
    return [
        {"name": f"inner_loop/dense/{tag}",
         "us_per_call": f"{t_dense * 1e6:.0f}",
         "derived": f"bytes_moved={_bytes_dense(d, nnz)};M={M};nnz={nnz}"},
        {"name": f"inner_loop/lazy/{tag}",
         "us_per_call": f"{t_lazy * 1e6:.0f}",
         "derived": (f"bytes_moved={_bytes_lazy(d, nnz)};M={M};nnz={nnz};"
                     f"speedup_vs_dense={speedup:.2f}x")},
    ]


def main(full: bool = False) -> List[Dict]:
    rows = []
    for d in SWEEP_D:
        for density in SWEEP_DENSITY:
            rows.extend(bench_point(d, density))
    return rows


if __name__ == "__main__":
    for r in main():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
