"""Dense vs lazy vs fused inner-epoch sweep — the tentpole perf
measurement.

One inner epoch = M prox-SVRG steps on a single worker shard.  Three
engines are timed on identical sample sequences:

* ``dense`` — O(M * d) elementwise traffic regardless of sparsity
  (`pscope._inner_loop`, fused Pallas prox tail);
* ``lazy``  — the PR-2 per-step scan (`pscope._lazy_inner_loop_ref`):
  support-restricted, but 4 gathers + 3 scatters + an int32 stamp
  scatter per step;
* ``fused`` — the epoch-planned engine (`pscope._lazy_inner_loop`):
  catch-up bookkeeping hoisted into one vectorized plan
  (`core.plan`), anchor operands pre-gathered per epoch, ONE gather +
  ONE scatter per step (`kernels.ops.fused_lazy_epoch`).

The data-only shard statics (duplicate sums, membership table) are
built outside the timed region — in the real system they are computed
once per run by `pscope.run`, exactly as the dense row excludes its
one-off CSR->dense materialization.  The per-epoch plan build IS
timed (it runs every outer round).

The sweep crosses d in {2^14, 2^16, 2^18} with density in {1%, 0.1%}
(the rcv1 -> kdd regime of Table 1) and reports wall-clock us_per_call
plus an analytic bytes-moved model for each path, so the roofline
crossover (see docs/kernels.md) is visible in the CSV.

Rows are named ``inner_loop/{path}/d{d}/rho{density}`` — the names the
``--json`` flag of benchmarks/run.py keys BENCH_inner_loop.json on.
``--smoke`` (or main(smoke=True)) runs a single small cell once — the
CI matrix uses it to keep all three engines' dispatch paths green.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_row, time_fn
from repro import obs
from repro.core import plan as plan_mod
from repro.core.prox import Regularizer
from repro.core.pscope import (_inner_loop, _lazy_inner_loop,
                               _lazy_inner_loop_ref)
from repro.core.svrg import logistic_h_prime
from repro.data.sparse import csr_to_dense, make_csr_classification

M = 64            # inner steps per epoch (the acceptance-criteria setting)
BATCH = 1         # b = 1 reproduces Algorithm 1
N_ROWS = 64       # shard rows; cost is step-count bound, not data bound
REPEATS = 13

SWEEP_D = (1 << 14, 1 << 16, 1 << 18)
SWEEP_DENSITY = (0.01, 0.001)

REG = Regularizer(1e-4, 1e-4)
ETA = 0.3


# The dense/lazy/fused per-epoch traffic models now live in
# `repro.obs.roofline.inner_epoch_bytes` — shared verbatim with the
# device-side `bytes_moved` counter in core.pscope, so the bench rows
# and the in-run counters cannot drift apart.

def _bytes_dense(d: int, nnz: int) -> int:
    return int(obs.roofline.inner_epoch_bytes("dense", d=d, M=M,
                                              b=BATCH, k=nnz))


def _bytes_lazy(d: int, nnz: int) -> int:
    return int(obs.roofline.inner_epoch_bytes("lazy", d=d, M=M,
                                              b=BATCH, k=nnz))


def _bytes_fused(d: int, nnz: int) -> int:
    return int(obs.roofline.inner_epoch_bytes("fused", d=d, M=M,
                                              b=BATCH, k=nnz))


def bench_point(d: int, density: float, seed: int = 0,
                repeats: int = REPEATS) -> List[Dict]:
    csr, y, _ = make_csr_classification(N_ROWS, d, density=density, seed=seed)
    nnz = csr.max_nnz
    y = jnp.asarray(y)
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(d).astype(np.float32) * 0.05)
    z = jnp.asarray(rng.randn(d).astype(np.float32) * 0.01)
    idx = jnp.asarray(rng.randint(0, N_ROWS, size=(M, BATCH)), jnp.int32)

    X = csr_to_dense(csr)
    # data-only statics: built once per run by the driver, not per epoch
    # (the production with_member policy: sort-plan on CPU)
    statics = jax.jit(lambda v, c: plan_mod.shard_statics(
        v, c, with_member=plan_mod.default_with_member(N_ROWS, nnz)))(
            csr.vals, csr.cols)
    jax.block_until_ready(statics.xdup)

    dense_fn = jax.jit(lambda u, Xk, yk, ix: _inner_loop(
        None, REG, ETA, u, w, z, Xk, yk, ix, h_prime=logistic_h_prime))
    lazy_fn = jax.jit(lambda u, v, c, yk, ix: _lazy_inner_loop_ref(
        logistic_h_prime, REG, ETA, u, w, z, v, c, yk, ix))
    fused_fn = jax.jit(lambda u, v, c, yk, ix, st: _lazy_inner_loop(
        logistic_h_prime, REG, ETA, u, w, z, v, c, yk, ix, statics=st))

    # correctness guard: a benchmark that drifted from equivalence would
    # be timing different algorithms
    u_d = dense_fn(w, X, y, idx)
    u_l = lazy_fn(w, csr.vals, csr.cols, y, idx)
    u_f = fused_fn(w, csr.vals, csr.cols, y, idx, statics)
    err_l = float(jnp.max(jnp.abs(u_d - u_l)))
    err_f = float(jnp.max(jnp.abs(u_d - u_f)))
    assert err_l < 1e-4, f"lazy/dense diverged at d={d}: {err_l}"
    assert err_f < 1e-4, f"fused/dense diverged at d={d}: {err_f}"

    # each engine timed in its own contiguous block (per-engine caches
    # stay warm with that engine's working set, as in production); the
    # min over repeats rejects the container's additive scheduler noise
    t_dense = time_fn(dense_fn, w, X, y, idx, repeats=repeats)
    t_lazy = time_fn(lazy_fn, w, csr.vals, csr.cols, y, idx,
                     repeats=repeats)
    t_fused = time_fn(fused_fn, w, csr.vals, csr.cols, y, idx, statics,
                      repeats=repeats)

    # the production surface: inner_path="auto" dispatches each run to
    # the cost-model winner, so its steady-state cost IS the picked
    # engine's cost (the model evaluates once per run, host-side)
    picked = plan_mod.choose_inner_path(d, M, BATCH, nnz)
    t_auto = t_dense if picked == "dense" else t_fused

    tag = f"d{d}/rho{density:g}"
    # rows go through bench_row so each carries a real pct_peak (the
    # modeled bytes against THIS host's measured roofline) next to the
    # same bytes_moved string the CSV has always printed
    b_auto = _bytes_dense(d, nnz) if picked == "dense" \
        else _bytes_fused(d, nnz)
    return [
        bench_row(
            f"inner_loop/dense/{tag}", t_dense,
            f"bytes_moved={_bytes_dense(d, nnz)};M={M};nnz={nnz}",
            bytes_moved=_bytes_dense(d, nnz)),
        bench_row(
            f"inner_loop/lazy/{tag}", t_lazy,
            (f"bytes_moved={_bytes_lazy(d, nnz)};M={M};nnz={nnz};"
             f"speedup_vs_dense={t_dense / max(t_lazy, 1e-12):.2f}x"),
            bytes_moved=_bytes_lazy(d, nnz)),
        bench_row(
            f"inner_loop/fused/{tag}", t_fused,
            (f"bytes_moved={_bytes_fused(d, nnz)};M={M};nnz={nnz};"
             f"speedup_vs_dense={t_dense / max(t_fused, 1e-12):.2f}x;"
             f"speedup_vs_lazy={t_lazy / max(t_fused, 1e-12):.2f}x"),
            bytes_moved=_bytes_fused(d, nnz)),
        bench_row(
            f"inner_loop/auto/{tag}", t_auto,
            (f"picked={picked};M={M};nnz={nnz};"
             f"speedup_vs_dense={t_dense / max(t_auto, 1e-12):.2f}x;"
             f"speedup_vs_lazy={t_lazy / max(t_auto, 1e-12):.2f}x"),
            bytes_moved=b_auto),
    ]


def main(full: bool = False, smoke: bool = False) -> List[Dict]:
    # `full` is accepted for benchmarks.run harness uniformity; this
    # sweep's grid is fixed (the acceptance cells) and does not grow.
    if smoke:
        return bench_point(1 << 12, 0.01, repeats=2)
    rows = []
    for d in SWEEP_D:
        for density in SWEEP_DENSITY:
            rows.extend(bench_point(d, density))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny cell, 2 repeats (CI matrix)")
    args = ap.parse_args()
    for r in main(smoke=args.smoke):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
