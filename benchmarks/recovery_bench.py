"""Section-6 recovery strategy benchmark: exact equivalence + work saved.

Compares the dense inner loop (O(d) per step) against the block-lazy
Algorithm-2 loop (O(nnz) per step + closed-form catch-up) and the
Pallas lazy_prox kernel, on rcv1-like sparse data.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.recovery import (lazy_inner_loop, dense_inner_loop_linear,
                                 recovery_catch_up)
from repro.core.svrg import logistic_h_prime
from repro.data.synthetic import (make_sparse_classification,
                                  make_block_sparse, pad_features)
from repro.kernels import ops as kops


def main() -> List[Dict]:
    rows = []
    X, y, _ = make_sparse_classification(256, 4096, density=0.01, seed=0)
    X = pad_features(X, 128)
    Xb, bids = make_block_sparse(X, 128)
    d = X.shape[1]
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(d).astype(np.float32) * 0.1)
    z = jnp.asarray(rng.randn(d).astype(np.float32) * 0.02)
    idx = jnp.asarray(rng.randint(0, 256, size=128).astype(np.int32))
    eta, lam1, lam2 = 0.1, 1e-4, 1e-4

    dense = jax.jit(lambda: dense_inner_loop_linear(
        logistic_h_prime, lam1, lam2, eta, w, w, z, jnp.asarray(X),
        jnp.asarray(y), idx))
    lazy = jax.jit(lambda: lazy_inner_loop(
        logistic_h_prime, lam1, lam2, eta, w, w, z, jnp.asarray(Xb),
        jnp.asarray(y), jnp.asarray(bids), idx, 128))

    u_dense = dense().block_until_ready()
    u_lazy = lazy().block_until_ready()
    err = float(jnp.max(jnp.abs(u_dense - u_lazy)))

    def t(fn, n=5):
        fn()
        t0 = time.perf_counter()
        for _ in range(n):
            fn().block_until_ready()
        return (time.perf_counter() - t0) / n

    td, tl = t(dense), t(lazy)
    active = Xb.shape[1] * 128
    rows.append({
        "name": "recovery/inner_loop_128steps",
        "us_per_call": f"{tl * 1e6:.0f}",
        "derived": (f"dense_us={td * 1e6:.0f};equiv_err={err:.1e};"
                    f"touched_frac={active / d:.4f};"
                    f"coord_work_ratio={active / d:.4f}"),
    })

    # kernel throughput: catch-up of 1M coords
    u1 = jnp.asarray(rng.randn(1 << 20).astype(np.float32))
    z1 = jnp.asarray(rng.randn(1 << 20).astype(np.float32) * 0.01)
    q1 = jnp.asarray(rng.randint(0, 512, 1 << 20).astype(np.int32))
    kern = jax.jit(lambda: kops.lazy_prox(u1, z1, q1, eta=eta, lam1=lam1,
                                          lam2=lam2))
    ref = jax.jit(lambda: recovery_catch_up(u1, z1, q1, eta, lam1, lam2))
    tk, tr = t(kern, 3), t(ref, 3)
    errk = float(jnp.max(jnp.abs(kern() - ref())))
    rows.append({
        "name": "recovery/lazy_prox_kernel_1M",
        "us_per_call": f"{tk * 1e6:.0f}",
        "derived": f"ref_us={tr * 1e6:.0f};allclose_err={errk:.1e}",
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
