"""Roofline report: reads the dry-run artifacts (results/dryrun/*.json)
AND the cross-PR perf trails (BENCH_*.json at the repo root) and emits
summary CSV rows plus %-of-peak markdown tables (EXPERIMENTS.md
section Roofline).

The perf-trail half keys on the bench-rows/v2 schema written by
``benchmarks/run.py --json``: each row's `pct_peak` (modeled traffic
vs the measured host roofline, see repro.obs.roofline) and the file's
`host` fingerprint."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(directory="results/dryrun") -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(ROOT, directory, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def load_bench(pattern="BENCH_*.json") -> List[dict]:
    """The perf-trail snapshots at the repo root (any schema version;
    pre-v2 rows simply have no pct_peak to report)."""
    out = []
    for f in sorted(glob.glob(os.path.join(ROOT, pattern))):
        try:
            with open(f) as fh:
                doc = json.load(fh)
        except (OSError, ValueError):
            continue
        if isinstance(doc, dict) and isinstance(doc.get("rows"), list):
            doc["_file"] = os.path.basename(f)
            out.append(doc)
    return out


def _trail_summary(doc: dict) -> Dict:
    rows = doc.get("rows", [])
    annotated = [(r["name"], float(r["pct_peak"])) for r in rows
                 if isinstance(r.get("pct_peak"), (int, float))]
    host = doc.get("host", {})
    derived = (f"schema={doc.get('schema')};rows={len(rows)};"
               f"annotated={len(annotated)}")
    if annotated:
        top = max(annotated, key=lambda t: t[1])
        derived += (f";max_pct_peak={top[1] * 100:.1f}%"
                    f";max_at={top[0]}")
    if host:
        derived += (f";backend={host.get('backend', '?')}"
                    f";host={host.get('host', '?')}")
    return {"name": f"roofline/trail/{doc['_file']}",
            "us_per_call": "", "derived": derived}


def main() -> List[Dict]:
    rows = [_trail_summary(doc) for doc in load_bench()]
    for r in load():
        if r.get("status") != "ok":
            rows.append({"name": f"dryrun/{r['arch']}/{r['shape']}/"
                                 f"{r['mesh']}/{r['step']}",
                         "us_per_call": "",
                         "derived": f"status={r.get('status')}"})
            continue
        terms = r["roofline"]
        rows.append({
            "name": (f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}/"
                     f"{r['step']}"),
            "us_per_call": f"{r.get('compile_s', 0) * 1e6:.0f}",
            "derived": (
                f"fits={r['fits_hbm']};bottleneck={terms['bottleneck']};"
                f"t_comp={terms['t_compute']:.3g};"
                f"t_mem={terms['t_memory']:.3g};"
                f"t_coll={terms['t_collective']:.3g};"
                f"useful={r.get('useful_ratio') or 0:.3f}"),
        })
    return rows


def markdown_table(directory="results/dryrun") -> str:
    lines = [
        "| arch | shape | mesh | step | fits | t_comp (s) | t_mem (s) | "
        "t_coll (s) | bottleneck | useful |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(directory):
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
                f"| skip | — | — | — | {r.get('reason', '')[:40]} | — |")
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
                f"| **{r.get('status')}** | — | — | — | — | — |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | "
            f"{t['t_compute']:.3g} | {t['t_memory']:.3g} | "
            f"{t['t_collective']:.3g} | {t['bottleneck'][2:]} | "
            f"{(r.get('useful_ratio') or 0):.2f} |")
    return "\n".join(lines)


def bench_markdown_table(pattern="BENCH_*.json") -> str:
    """%-of-peak table over every annotated perf-trail row."""
    lines = [
        "| trail | row | us/call | %-peak | bound | backend | host |",
        "|---|---|---|---|---|---|---|",
    ]
    for doc in load_bench(pattern):
        host = doc.get("host", {})
        for r in doc.get("rows", []):
            pct = r.get("pct_peak")
            pct_s = (f"{pct * 100:.1f}%"
                     if isinstance(pct, (int, float)) else "—")
            lines.append(
                f"| {doc['_file']} | {r.get('name', '?')} | "
                f"{r.get('us_per_call', '')} | {pct_s} | "
                f"{r.get('roofline_bound', '—')} | "
                f"{r.get('backend', host.get('backend', '?'))} | "
                f"{r.get('host', host.get('host', '?'))} |")
    return "\n".join(lines)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
    print()
    print(bench_markdown_table())
