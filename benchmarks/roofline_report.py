"""Roofline report: reads results/dryrun/*.json into the per-cell table
(EXPERIMENTS.md section Roofline) and emits summary CSV rows."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(directory="results/dryrun") -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(ROOT, directory, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def main() -> List[Dict]:
    rows = []
    for r in load():
        if r.get("status") != "ok":
            rows.append({"name": f"dryrun/{r['arch']}/{r['shape']}/"
                                 f"{r['mesh']}/{r['step']}",
                         "us_per_call": "",
                         "derived": f"status={r.get('status')}"})
            continue
        terms = r["roofline"]
        rows.append({
            "name": (f"dryrun/{r['arch']}/{r['shape']}/{r['mesh']}/"
                     f"{r['step']}"),
            "us_per_call": f"{r.get('compile_s', 0) * 1e6:.0f}",
            "derived": (
                f"fits={r['fits_hbm']};bottleneck={terms['bottleneck']};"
                f"t_comp={terms['t_compute']:.3g};"
                f"t_mem={terms['t_memory']:.3g};"
                f"t_coll={terms['t_collective']:.3g};"
                f"useful={r.get('useful_ratio') or 0:.3f}"),
        })
    return rows


def markdown_table(directory="results/dryrun") -> str:
    lines = [
        "| arch | shape | mesh | step | fits | t_comp (s) | t_mem (s) | "
        "t_coll (s) | bottleneck | useful |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(directory):
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
                f"| skip | — | — | — | {r.get('reason', '')[:40]} | — |")
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
                f"| **{r.get('status')}** | — | — | — | — | — |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | "
            f"{t['t_compute']:.3g} | {t['t_memory']:.3g} | "
            f"{t['t_collective']:.3g} | {t['bottleneck'][2:]} | "
            f"{(r.get('useful_ratio') or 0):.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
