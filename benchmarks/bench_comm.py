"""Communication-volume benchmark: the BENCH_comm.json perf trail.

The paper's communication-efficiency claim (Section 5: one outer round
of CALL moves two d-vectors, independent of n) audited against the
COMPILED program, not the analytic model alone:

    comm/hlo/p{p}_n{n}_d{d}   all-reduce bytes per outer round counted
                              from the lowered HLO of the distributed
                              outer step (`roofline.analyze_hlo`), plus
                              the step's wall time as `us_per_call`
    comm/trace/d{d}           `Trace.comm` accounting of the
                              "pscope_mesh" registry solver (bytes, ==
                              analytic 2*d*itemsize per round)

Every run asserts the two load-bearing properties:

  * n-independence — doubling n leaves the per-round all-reduce bytes
    bit-identical (the inner loop is collective-free; only the anchor
    gradient psum and the iterate average touch the wire);
  * d-linearity — doubling d doubles them.

jax pins the host device count at first backend use, so the sweep runs
in a forked child with ``XLA_FLAGS=--xla_force_host_platform_device_
count=p`` (same pattern as tests/distributed_harness.py); this module
therefore works both standalone and via `benchmarks.run` (which has
already imported jax on a single device).

    PYTHONPATH=src python -m benchmarks.bench_comm [--smoke|--full]
    PYTHONPATH=src python -m benchmarks.run --only comm --json
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_ROWS_TAG = "BENCH_COMM_ROWS "

# (n, d) sweep; the first entry's shape is doubled in each direction by
# the assertion pairs below, so keep {n, 2n} x {d, 2d} in the grid.
_GRID_SMOKE = [(256, 32), (512, 32), (256, 64)]
_GRID_FULL = _GRID_SMOKE + [(1024, 64), (1024, 256)]

_CHILD = """
import json, time
import numpy as np
import jax, jax.numpy as jnp

from repro.core import LOGISTIC, PScopeConfig, Regularizer
from repro.core.pscope import init_state, make_distributed_outer_step_core
from repro.launch import roofline as rf
from repro.launch.mesh import comm_bytes_per_round

P_WORKERS = {p}
GRID = {grid!r}
TRACE_D = 32

mesh = jax.make_mesh((P_WORKERS,), ("workers",))
reg = Regularizer(1e-3, 1e-3)
rows = []

measured = {{}}
for n, d in GRID:
    cfg = PScopeConfig(eta=0.5, inner_steps=16, inner_batch=2,
                       outer_steps=1)
    step = make_distributed_outer_step_core(LOGISTIC, reg, cfg, mesh,
                                            "workers")
    X = jnp.zeros((n, d)); y = jnp.zeros((n,))
    args = (init_state(jnp.zeros(d)), X, y, None)
    compiled = jax.jit(step).lower(*args).compile()
    ar_bytes = rf.analyze_hlo(compiled.as_text()).op_bytes.get(
        "all-reduce", 0.0)
    measured[(n, d)] = ar_bytes
    jax.block_until_ready(compiled(*args))          # warmup done at lower
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        ts.append(time.perf_counter() - t0)
    rows.append({{
        "name": f"comm/hlo/p{{P_WORKERS}}_n{{n}}_d{{d}}",
        "us_per_call": f"{{min(ts) * 1e6:.0f}}",
        "derived": (f"allreduce_bytes_per_round={{ar_bytes:.0f}};"
                    f"analytic_wire_bytes={{comm_bytes_per_round(d):.0f}};"
                    f"p={{P_WORKERS}};n={{n}};d={{d}}"),
    }})

# the two properties the trail regression-pins
(n0, d0) = GRID[0]
assert measured[(n0, d0)] > 0
assert measured[(n0, d0)] == measured[(2 * n0, d0)], (
    "per-round collective bytes grew with n", measured)
b_d, b_2d = measured[(n0, d0)], measured[(n0, 2 * d0)]
assert abs(b_2d - 2 * b_d) <= 0.1 * b_d, (
    "per-round collective bytes not O(d)", measured)

# Trace.comm accounting through the registry driver
from repro.core.partition import build_partition
from repro.core.solvers import SolverConfig, run as run_solver
from repro.data.synthetic import make_sparse_classification

X, y, _ = make_sparse_classification(8 * TRACE_D, TRACE_D, density=0.2,
                                     seed=0)
part = build_partition("uniform", X, y, P_WORKERS)
scfg = SolverConfig(rounds=3, inner_epochs=0.5)
t0 = time.perf_counter()
tr = run_solver("pscope_mesh", LOGISTIC, reg, part, scfg)
secs = time.perf_counter() - t0
per_round = comm_bytes_per_round(TRACE_D)
assert tr.meta["comm_units"] == "bytes"
assert np.all(np.diff(tr.comm) == per_round), tr.comm
rows.append({{
    "name": f"comm/trace/d{{TRACE_D}}",
    "us_per_call": f"{{secs * 1e6:.0f}}",
    "derived": (f"comm_bytes_per_round={{per_round:.0f}};"
                f"rounds={{scfg.rounds}};comm_total={{tr.comm[-1]:.0f}};"
                f"units={{tr.meta['comm_units']}}"),
}})

print({tag!r} + json.dumps(rows), flush=True)
"""


def _run_child(p: int, grid) -> List[Dict]:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={p}"
                        ).strip()
    code = textwrap.dedent(_CHILD).format(p=p, grid=list(grid),
                                          tag=_ROWS_TAG)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_comm child failed:\n"
                           f"{proc.stderr[-2500:]}")
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith(_ROWS_TAG)]
    if not lines:
        raise RuntimeError(f"bench_comm child produced no rows:\n"
                           f"{proc.stdout[-2500:]}")
    return json.loads(lines[-1][len(_ROWS_TAG):])


def main(full: bool = False, smoke: bool = False) -> List[Dict]:
    grid = _GRID_FULL if full else _GRID_SMOKE
    rows = _run_child(4, grid)
    if smoke:
        print("bench_comm smoke OK: per-round collective bytes "
              "independent of n, linear in d", file=sys.stderr)
    return rows


if __name__ == "__main__":
    ap_full = "--full" in sys.argv
    ap_smoke = "--smoke" in sys.argv
    out = main(full=ap_full, smoke=ap_smoke)
    print("name,us_per_call,derived")
    for r in out:
        print(f"{r['name']},{r.get('us_per_call', '')},"
              f"{r.get('derived', '')}")
