"""Partition-engine benchmark: estimator batching + optimizer payoff.

Two measurement families, both emitted as ``partition/*`` rows (the
names ``benchmarks/run.py --json`` keys BENCH_partition.json on — the
partition-engine analogue of BENCH_inner_loop.json):

  * ``partition/estimator/{loop,batched}`` — the Definition-5 gamma
    estimate on a Section-7.4 scheme at p=8 workers x S=8 anchors:
    the removed sequential implementation (p*S Python FISTA runs,
    re-traced every call) vs the one-XLA-call batched estimator of
    `repro.partition.metrics`.  The batched row's derived field
    records the speedup and the max deviation from the loop result
    (the equivalence guard — a benchmark that drifted from
    equivalence would be timing two different algorithms).

  * ``partition/optimizer/<scheme>`` — the greedy swap optimizer's
    surrogate-gamma trajectory from each skewed seed partition:
    gamma~ before/after, accepted swaps, candidate evaluations.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from repro.core import LOGISTIC, Regularizer
from repro.core.baselines.fista import fista_history
from repro.data.synthetic import make_sparse_classification
from repro.partition import (build_partition, gamma_estimate,
                             refine_partition)
from repro.partition.metrics import gamma_estimate_loop

P_WORKERS = 8     # the acceptance-criteria grid: p=8 workers ...
S_ANCHORS = 8     # ... x S=8 Monte-Carlo anchors
FISTA_ITERS = 200
N, D = 512, 32


def _data():
    X, y, _ = make_sparse_classification(N, D, density=0.4, seed=0)
    return jnp.asarray(X), jnp.asarray(y)


def bench_estimator(X, y) -> List[Dict]:
    reg = Regularizer(1e-2, 1e-3)
    w_star, fh = fista_history(LOGISTIC, reg, X, y, jnp.zeros(D),
                               iters=1500, record_every=1500)
    p_star = fh[-1]
    part = build_partition("split", X, y, P_WORKERS)
    kw = dict(eps=0.05, num_samples=S_ANCHORS, iters=FISTA_ITERS)

    # warm the batched path so its row times the steady state; the loop
    # path has no steady state to warm — it re-traces p*S FISTA closures
    # on every call, which is exactly the cost being replaced
    g_batched = gamma_estimate(LOGISTIC, reg, part.Xp, part.yp, w_star,
                               p_star, **kw)
    t0 = time.perf_counter()
    g_batched = gamma_estimate(LOGISTIC, reg, part.Xp, part.yp, w_star,
                               p_star, **kw)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    g_loop = gamma_estimate_loop(LOGISTIC, reg, part.Xp, part.yp, w_star,
                                 p_star, **kw)
    t_loop = time.perf_counter() - t0

    err = abs(g_batched - g_loop)
    speedup = t_loop / max(t_batched, 1e-12)
    tag = f"p{P_WORKERS}/S{S_ANCHORS}"
    return [
        {"name": f"partition/estimator/loop/{tag}",
         "us_per_call": f"{t_loop * 1e6:.0f}",
         "derived": f"gamma={g_loop:.6e};iters={FISTA_ITERS}"},
        {"name": f"partition/estimator/batched/{tag}",
         "us_per_call": f"{t_batched * 1e6:.0f}",
         "derived": (f"gamma={g_batched:.6e};iters={FISTA_ITERS};"
                     f"speedup_vs_loop={speedup:.1f}x;"
                     f"abs_err_vs_loop={err:.2e}")},
    ]


def bench_optimizer(X, y) -> List[Dict]:
    Xn = np.asarray(X)
    rows = []
    for scheme in ("split", "dirichlet", "feature_clusters"):
        part = build_partition(scheme, X, y, P_WORKERS)
        t0 = time.perf_counter()
        res = refine_partition(Xn, part.idx, seed=0)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"partition/optimizer/{scheme}",
            "us_per_call": f"{dt * 1e6:.0f}",
            "derived": (f"gamma0={res.gamma_initial:.3e};"
                        f"gammaT={res.gamma_final:.3e};"
                        f"accepted={res.accepted};"
                        f"evaluated={res.evaluated}"),
        })
    return rows


def main(full: bool = False) -> List[Dict]:
    X, y = _data()
    return bench_estimator(X, y) + bench_optimizer(X, y)


if __name__ == "__main__":
    for r in main():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
