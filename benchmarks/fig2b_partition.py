"""Figure 2(b) analogue: pSCOPE under every registered partition
scheme — the paper's four Section-7.4 partitions plus the harder
scenarios and the `optimized:*` variants from `repro.partition.schemes`.

Sweeps the scheme registry through the solver registry — registering a
new scheme there adds a row here with no other change.  Each row also
reports the Lemma-5 surrogate gamma~ of the built partition, so the
paper's claim (smaller gamma => faster convergence) and the optimizer's
effect (optimized:split strictly below split) are visible in one CSV.

Caveat on cross-scheme gap comparisons: each trace records the
objective over its own shard multiset, so schemes that truncate
(split) or resample rows (dup_heavy) measure a slightly different
objective than the full-data P* — gaps can even go negative.  Rows
with identical multisets (split vs optimized:split — swaps preserve
the row multiset exactly) remain directly comparable.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import build_problem, reference_optimum
from repro.core import solvers
from repro.core.solvers import SolverConfig
from repro.partition import PARTITION_SCHEMES, build_partition, gamma_surrogate

# display names matching the paper's pi notation
SCHEME_LABELS = {"replicated": "pi_star", "uniform": "pi1_uniform",
                 "skew75": "pi2_skew75", "split": "pi3_split"}


def main() -> List[Dict]:
    rows = []
    X, y, obj, reg = build_problem("cov", "logistic", scale=0.05)
    p_star = reference_optimum(obj, reg, X, y)
    for scheme in PARTITION_SCHEMES:
        part = build_partition(scheme, X, y, 8)
        gamma_sur = gamma_surrogate(part)
        # inner_epochs=8: enough local work per round that partition
        # quality visibly moves the trace (the Theorem-2 regime), which
        # is what separates split from optimized:split here
        cfg = SolverConfig(rounds=10, eta=0.5, inner_epochs=8.0)
        trace = solvers.run("pscope", obj, reg, part, cfg)
        gaps = ";".join(f"{g:.2e}" for g in trace.suboptimality(p_star)[:8])
        label = SCHEME_LABELS.get(scheme, scheme)
        rows.append({
            "name": f"fig2b/{label}",
            "us_per_call": f"{trace.seconds[-1] / max(trace.rounds, 1) * 1e6:.0f}",
            "derived": (f"final_gap={trace.gap(p_star):.3e};"
                        f"gamma_sur={gamma_sur:.3e};traj={gaps}"),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
