"""Figure 2(b) analogue: pSCOPE under pi*, uniform, 75/25-skew and
fully-split partitions."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_problem, reference_optimum
from repro.core import PScopeConfig, run
from repro.core.partition import (uniform_partition, label_skew_partition,
                                  replicated_partition, stack_partition)


def main() -> List[Dict]:
    rows = []
    X, y, obj, reg = build_problem("cov", "logistic", scale=0.05)
    n, d = X.shape
    p_star = reference_optimum(obj, reg, X, y)
    parts = {
        "pi_star": replicated_partition(n, 8),
        "pi1_uniform": uniform_partition(jax.random.PRNGKey(0), n, 8),
        "pi2_skew75": label_skew_partition(np.asarray(y), 8, 0.75),
        "pi3_split": label_skew_partition(np.asarray(y), 8, 1.0),
    }
    for name, idx in parts.items():
        Xp, yp = stack_partition(X, y, idx)
        n_k = Xp.shape[1]
        cfg = PScopeConfig(eta=0.5, inner_steps=2 * n_k, inner_batch=1,
                           outer_steps=10)
        t0 = time.perf_counter()
        _, hist = run(obj, reg, Xp, yp, jnp.zeros(d), cfg)
        dt = time.perf_counter() - t0
        gaps = ";".join(f"{h - p_star:.2e}" for h in hist[:8])
        rows.append({
            "name": f"fig2b/{name}",
            "us_per_call": f"{dt / 10 * 1e6:.0f}",
            "derived": f"final_gap={hist[-1] - p_star:.3e};traj={gaps}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
