"""Figure 2(b) analogue: pSCOPE under the paper's four Section-7.4
partitions (pi*, uniform, 75/25-skew, full class split).

Sweeps `core.partition.PARTITION_SCHEMES` through the solver registry —
registering a new scheme there adds a row here with no other change.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import build_problem, reference_optimum
from repro.core import solvers
from repro.core.partition import PARTITION_SCHEMES, build_partition
from repro.core.solvers import SolverConfig

# display names matching the paper's pi notation
SCHEME_LABELS = {"replicated": "pi_star", "uniform": "pi1_uniform",
                 "skew75": "pi2_skew75", "split": "pi3_split"}


def main() -> List[Dict]:
    rows = []
    X, y, obj, reg = build_problem("cov", "logistic", scale=0.05)
    p_star = reference_optimum(obj, reg, X, y)
    for scheme in PARTITION_SCHEMES:
        part = build_partition(scheme, X, y, 8)
        cfg = SolverConfig(rounds=10, eta=0.5, inner_epochs=2.0)
        trace = solvers.run("pscope", obj, reg, part, cfg)
        gaps = ";".join(f"{g:.2e}" for g in trace.suboptimality(p_star)[:8])
        label = SCHEME_LABELS.get(scheme, scheme)
        rows.append({
            "name": f"fig2b/{label}",
            "us_per_call": f"{trace.seconds[-1] / max(trace.rounds, 1) * 1e6:.0f}",
            "derived": f"final_gap={trace.gap(p_star):.3e};traj={gaps}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
