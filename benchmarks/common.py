"""Shared benchmark utilities: problem setup, time/epoch accounting,
CSV emission (`name,us_per_call,derived`)."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import Regularizer, LOGISTIC, LASSO
from repro.core.baselines.fista import fista_history
from repro.data.synthetic import make_dataset


def build_problem(name: str, model: str, scale: float = 0.05, seed: int = 0):
    """Returns (X, y, objective, regularizer)."""
    task = "regression" if model == "lasso" else "classification"
    X, y, _ = make_dataset(name, task=task, seed=seed, scale=scale)
    X, y = jnp.asarray(X), jnp.asarray(y)
    # paper's lambdas (Table 1): lam1 = 1e-5-ish, lam2 = 1e-5
    reg = (Regularizer(1e-4, 1e-4) if model == "logistic"
           else Regularizer(0.0, 1e-4))
    obj = LOGISTIC if model == "logistic" else LASSO
    return X, y, obj, reg


def reference_optimum(obj, reg, X, y, iters: int = 4000) -> float:
    _, hist = fista_history(obj, reg, X, y, jnp.zeros(X.shape[1]),
                            iters=iters, record_every=iters)
    return hist[-1]


def time_to_suboptimality(history: List[float], times: List[float],
                          p_star: float, eps: float = 1e-3):
    """First wall-time at which P(w) - P* <= eps (np.inf if never)."""
    for h, t in zip(history, times):
        if h - p_star <= eps:
            return t
    return float("inf")


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()
        self.marks: List[float] = [0.0]

    def mark(self):
        self.marks.append(time.perf_counter() - self.t0)
        return self.marks[-1]


def emit(rows: List[Dict]):
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
