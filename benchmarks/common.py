"""Shared benchmark utilities: problem setup, time/epoch accounting,
CSV emission (`name,us_per_call,derived`).

All figures sweep the `core.solvers` registry; `trace_row` turns the
`Trace` a registry run returns into one CSV row so every figure reports
the same derived metrics (final gap, time/comm-to-eps, rounds, NNZ).

`bench_row` / `stamp_row` is the one place the machine-readable row
schema lives: every row that lands in a BENCH_*.json trail carries the
host fingerprint, backend, timestamp, and (when the caller supplies a
byte/FLOP model) a `pct_peak` roofline annotation against the
*measured* host machine — so a perf-trail diff across PRs can tell a
code regression from a container change.
"""
from __future__ import annotations

import datetime
import functools
import platform
import re
import time
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import LOGISTIC, LASSO
from repro.core.partition import build_partition
from repro.core.baselines.fista import fista_history
from repro.core.solvers import Trace
from repro.data.synthetic import make_dataset


def time_fn(fn, *args, repeats: int = 7) -> float:
    """Min wall seconds per call, after a compile+warmup call.

    Every call — the warmup AND each timed repetition — is wrapped in
    `jax.block_until_ready`, so jax's async dispatch cannot return the
    future early and under-report `us_per_call`.  This matters doubly
    now that the scanned drivers batch whole trajectories into single
    dispatches: an unblocked timer would measure enqueue cost, not
    execution.  All timing loops in this package must go through here.

    The minimum (not the median) is reported: scheduler noise on the
    small shared-CPU containers this runs in is strictly additive, so
    the min is the standard consistent estimator of true cost, and
    cross-engine ratios stay comparable across load conditions.
    """
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def build_problem(name: str, model: str, scale: float = 0.05, seed: int = 0):
    """Returns (X, y, objective, regularizer)."""
    from repro.datasets.registry import default_regularizer
    task = "regression" if model == "lasso" else "classification"
    X, y, _ = make_dataset(name, task=task, seed=seed, scale=scale)
    X, y = jnp.asarray(X), jnp.asarray(y)
    # paper's lambdas (Table 1); the one copy of the default lives in
    # the dataset registry so registry and synthetic problems agree
    reg = default_regularizer(model)
    obj = LOGISTIC if model == "logistic" else LASSO
    return X, y, obj, reg


def build_partitioned_problem(name: str, model: str, p: int = 8,
                              scheme: str = "uniform", scale: float = 0.05,
                              seed: int = 0):
    """Returns (objective, regularizer, Partition) ready for solvers.run."""
    X, y, obj, reg = build_problem(name, model, scale=scale, seed=seed)
    part = build_partition(scheme, X, y, p, seed=seed)
    return obj, reg, part


def build_registry_problem(name: str, model: str = None, p: int = 8,
                           scale: float = 0.05, seed: int = 0,
                           placement: str = "sequential"):
    """Like `build_partitioned_problem` but resolved through the
    `repro.datasets` registry: the fixture is real LIBSVM text pushed
    through the full parse -> shard -> mmap ingestion path (the
    `--dataset` flag of fig1/table2 lands here).  The Partition's data
    is mmap-backed."""
    from repro import datasets
    from repro.core import OBJECTIVES
    from repro.datasets.registry import default_regularizer
    loaded = datasets.load(name, p=p, scale=scale, seed=seed,
                           placement=placement)
    if model is None or model == loaded.profile.model:
        return loaded.objective, loaded.regularizer, loaded.partition()
    # explicit cross-task override (e.g. lasso on +-1 labels)
    return (OBJECTIVES[model], default_regularizer(model),
            loaded.partition())


def trace_row(trace: Trace, prefix: str, p_star: float,
              eps: float = 1e-3) -> Dict:
    """One `name,us_per_call,derived` row from a registry Trace."""
    per = trace.seconds[-1] / max(trace.rounds, 1)
    tts = trace.time_to(p_star, eps)
    comm = trace.comm_to(p_star, eps)
    return {
        "name": f"{prefix}/{trace.solver}",
        "us_per_call": f"{per * 1e6:.0f}",
        "derived": (f"final_gap={trace.gap(p_star):.2e};"
                    f"tts@{eps:g}={tts if np.isfinite(tts) else 'inf'};"
                    f"comm@{eps:g}={comm if np.isfinite(comm) else 'inf'};"
                    f"rounds={trace.rounds};nnz={trace.nnz[-1]}"),
    }


def reference_optimum(obj, reg, X, y, iters: int = 4000) -> float:
    _, hist = fista_history(obj, reg, X, y, jnp.zeros(X.shape[1]),
                            iters=iters, record_every=iters)
    return hist[-1]


def emit(rows: List[Dict]):
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")


# --------------------------------------------------------------------------
# machine-readable row schema (BENCH_*.json trails)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _fingerprint() -> Dict[str, Any]:
    dev = jax.devices()[0]
    host = obs.roofline.host_machine()
    return {
        "host": platform.node() or platform.machine(),
        "machine": platform.machine(),
        "backend": dev.platform,
        "device": getattr(dev, "device_kind", str(dev)),
        "device_count": jax.device_count(),
        "jax": jax.__version__,
        "roofline_machine": host.name,
        "host_peak_gbps": round(host.hbm_bw / 1e9, 1),
        "host_peak_gflops": round(host.peak_flops / 1e9, 1),
    }


def host_fingerprint() -> Dict[str, Any]:
    """Who measured these numbers: hostname, arch, jax backend/device,
    and the micro-benchmarked peak rates of this host (the denominator
    of every `pct_peak` in the same file).  Cached per process."""
    return dict(_fingerprint())


_BYTES_RE = re.compile(r"bytes_moved=([0-9]+(?:\.[0-9]+)?)")


def stamp_row(row: Dict[str, Any], *, bytes_moved: float = 0.0,
              flops: float = 0.0, seconds: Optional[float] = None,
              machine=None) -> Dict[str, Any]:
    """Return `row` stamped with the shared perf-trail schema: host +
    backend identity, a UTC timestamp, and a `pct_peak` roofline
    annotation (None when the row carries no byte/FLOP model to
    compute one from).  Existing keys win — a suite that computed its
    own pct_peak is not second-guessed.

    When `bytes_moved` is not passed, the row's `derived` string is
    scanned for the conventional ``bytes_moved=N`` term, so legacy
    rows pick up real annotations with no per-suite changes.
    """
    out = dict(row)
    fp = host_fingerprint()
    out.setdefault("host", fp["host"])
    out.setdefault("backend", fp["backend"])
    out.setdefault("device", fp["device"])
    out.setdefault("timestamp", datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds"))
    if "pct_peak" not in out:
        if seconds is None:
            try:
                seconds = float(out.get("us_per_call", "")) / 1e6
            except (TypeError, ValueError):
                seconds = None
        if not bytes_moved:
            m = _BYTES_RE.search(str(out.get("derived", "")))
            if m:
                bytes_moved = float(m.group(1))
        if seconds and (bytes_moved or flops):
            rl = obs.roofline.pct_peak(seconds=seconds,
                                       bytes_moved=bytes_moved,
                                       flops=flops, machine=machine)
            out["pct_peak"] = round(rl["pct_peak"], 6)
            out["roofline_bound"] = rl["bound"]
        else:
            out["pct_peak"] = None
    return out


def bench_row(name: str, seconds: float, derived: str = "", *,
              bytes_moved: float = 0.0, flops: float = 0.0,
              machine=None, **extra) -> Dict[str, Any]:
    """Build one fully-stamped perf-trail row from a measured time."""
    row = {"name": name, "us_per_call": f"{seconds * 1e6:.0f}",
           "derived": derived, **extra}
    return stamp_row(row, bytes_moved=bytes_moved, flops=flops,
                     seconds=seconds, machine=machine)
