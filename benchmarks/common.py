"""Shared benchmark utilities: problem setup, time/epoch accounting,
CSV emission (`name,us_per_call,derived`).

All figures sweep the `core.solvers` registry; `trace_row` turns the
`Trace` a registry run returns into one CSV row so every figure reports
the same derived metrics (final gap, time/comm-to-eps, rounds, NNZ).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import LOGISTIC, LASSO
from repro.core.baselines.fista import fista_history
from repro.core.partition import build_partition
from repro.core.solvers import Trace
from repro.data.synthetic import make_dataset


def time_fn(fn, *args, repeats: int = 7) -> float:
    """Min wall seconds per call, after a compile+warmup call.

    Every call — the warmup AND each timed repetition — is wrapped in
    `jax.block_until_ready`, so jax's async dispatch cannot return the
    future early and under-report `us_per_call`.  This matters doubly
    now that the scanned drivers batch whole trajectories into single
    dispatches: an unblocked timer would measure enqueue cost, not
    execution.  All timing loops in this package must go through here.

    The minimum (not the median) is reported: scheduler noise on the
    small shared-CPU containers this runs in is strictly additive, so
    the min is the standard consistent estimator of true cost, and
    cross-engine ratios stay comparable across load conditions.
    """
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def build_problem(name: str, model: str, scale: float = 0.05, seed: int = 0):
    """Returns (X, y, objective, regularizer)."""
    from repro.datasets.registry import default_regularizer
    task = "regression" if model == "lasso" else "classification"
    X, y, _ = make_dataset(name, task=task, seed=seed, scale=scale)
    X, y = jnp.asarray(X), jnp.asarray(y)
    # paper's lambdas (Table 1); the one copy of the default lives in
    # the dataset registry so registry and synthetic problems agree
    reg = default_regularizer(model)
    obj = LOGISTIC if model == "logistic" else LASSO
    return X, y, obj, reg


def build_partitioned_problem(name: str, model: str, p: int = 8,
                              scheme: str = "uniform", scale: float = 0.05,
                              seed: int = 0):
    """Returns (objective, regularizer, Partition) ready for solvers.run."""
    X, y, obj, reg = build_problem(name, model, scale=scale, seed=seed)
    part = build_partition(scheme, X, y, p, seed=seed)
    return obj, reg, part


def build_registry_problem(name: str, model: str = None, p: int = 8,
                           scale: float = 0.05, seed: int = 0,
                           placement: str = "sequential"):
    """Like `build_partitioned_problem` but resolved through the
    `repro.datasets` registry: the fixture is real LIBSVM text pushed
    through the full parse -> shard -> mmap ingestion path (the
    `--dataset` flag of fig1/table2 lands here).  The Partition's data
    is mmap-backed."""
    from repro import datasets
    from repro.core import OBJECTIVES
    from repro.datasets.registry import default_regularizer
    loaded = datasets.load(name, p=p, scale=scale, seed=seed,
                           placement=placement)
    if model is None or model == loaded.profile.model:
        return loaded.objective, loaded.regularizer, loaded.partition()
    # explicit cross-task override (e.g. lasso on +-1 labels)
    return (OBJECTIVES[model], default_regularizer(model),
            loaded.partition())


def trace_row(trace: Trace, prefix: str, p_star: float,
              eps: float = 1e-3) -> Dict:
    """One `name,us_per_call,derived` row from a registry Trace."""
    per = trace.seconds[-1] / max(trace.rounds, 1)
    tts = trace.time_to(p_star, eps)
    comm = trace.comm_to(p_star, eps)
    return {
        "name": f"{prefix}/{trace.solver}",
        "us_per_call": f"{per * 1e6:.0f}",
        "derived": (f"final_gap={trace.gap(p_star):.2e};"
                    f"tts@{eps:g}={tts if np.isfinite(tts) else 'inf'};"
                    f"comm@{eps:g}={comm if np.isfinite(comm) else 'inf'};"
                    f"rounds={trace.rounds};nnz={trace.nnz[-1]}"),
    }


def reference_optimum(obj, reg, X, y, iters: int = 4000) -> float:
    _, hist = fista_history(obj, reg, X, y, jnp.zeros(X.shape[1]),
                            iters=iters, record_every=iters)
    return hist[-1]


def emit(rows: List[Dict]):
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
