"""Figure 1 analogue: pSCOPE vs baselines, LR-elastic-net and Lasso, on
the four Table-1 dataset analogues.  Reports epochs-normalized
convergence and wall time to 1e-3 suboptimality.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (build_problem, reference_optimum,
                               time_to_suboptimality)
from repro.core import PScopeConfig, run
from repro.core.baselines import (fista_history, pgd_history,
                                  prox_svrg_history, dpsgd_history,
                                  dpsvrg_history, admm_history,
                                  owlqn_history, dbcd_history,
                                  cocoa_history)
from repro.core.partition import uniform_partition, stack_partition

P_WORKERS = 8
EPS = 1e-3


def run_dataset(ds: str, model: str, scale: float = 0.05) -> List[Dict]:
    X, y, obj, reg = build_problem(ds, model, scale=scale)
    n, d = X.shape
    p_star = reference_optimum(obj, reg, X, y)
    idx = uniform_partition(jax.random.PRNGKey(0), n, P_WORKERS)
    Xp, yp = stack_partition(X, y, idx)
    w0 = jnp.zeros(d)
    n_k = Xp.shape[1]
    rows = []

    def record(name, fn, epochs_per_round):
        t0 = time.perf_counter()
        _, hist = fn()
        dt = time.perf_counter() - t0
        per = dt / max(len(hist) - 1, 1)
        times = [per * i for i in range(len(hist))]
        tts = time_to_suboptimality(hist, times, p_star, EPS)
        gap = hist[-1] - p_star
        rows.append({
            "name": f"fig1/{ds}/{model}/{name}",
            "us_per_call": f"{per * 1e6:.0f}",
            "derived": (f"final_gap={gap:.2e};tts@{EPS:g}="
                        f"{tts if np.isfinite(tts) else 'inf'};"
                        f"rounds={len(hist) - 1};"
                        f"epochs_per_round={epochs_per_round:g}"),
        })

    # pSCOPE: M = 3 local epochs per outer round (eta per Cor. 1 scale)
    cfg = PScopeConfig(eta=1.2, inner_steps=3 * n_k, inner_batch=1,
                       outer_steps=16)
    record("pscope", lambda: run(obj, reg, Xp, yp, w0, cfg), 3.0)
    record("fista", lambda: fista_history(obj, reg, X, y, w0, iters=120), 1.0)
    record("pgd", lambda: pgd_history(obj, reg, X, y, w0, iters=120), 1.0)
    record("prox_svrg",
           lambda: prox_svrg_history(obj, reg, X, y, w0, eta=0.5,
                                     inner_steps=2 * n, outer_steps=12), 3.0)
    record("dpsgd", lambda: dpsgd_history(obj, reg, Xp, yp, w0, eta0=0.5,
                                          steps=400, batch=8,
                                          record_every=20), 8.0 * 8 / n)
    record("dpsvrg",
           lambda: dpsvrg_history(obj, reg, Xp, yp, w0, eta=0.5,
                                  inner_steps=n_k, outer_steps=12), 2.0)
    record("admm", lambda: admm_history(obj, reg, Xp, yp, w0, rho=1.0,
                                        outer_steps=40), 20.0)
    record("owlqn", lambda: owlqn_history(obj, reg, X, y, w0, iters=60), 1.0)
    record("dbcd", lambda: dbcd_history(obj, reg, X, y, w0, p=P_WORKERS,
                                        outer_steps=120), 1.0)
    record("cocoa", lambda: cocoa_history(obj, reg, X, y, w0, p=P_WORKERS,
                                          outer_steps=60), 10.0)
    return rows


def main(full: bool = False) -> List[Dict]:
    rows = []
    datasets = ["cov", "rcv1"] + (["avazu", "kdd2012"] if full else [])
    for ds in datasets:
        for model in ("logistic", "lasso"):
            rows.extend(run_dataset(ds, model,
                                    scale=0.05 if ds in ("cov", "rcv1")
                                    else 0.02))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
