"""Figure 1 analogue: pSCOPE vs baselines, LR-elastic-net and Lasso, on
the four Table-1 dataset analogues.

Sweeps every solver in the `core.solvers` registry through the single
`solvers.run` entry point — adding a solver to the registry adds it to
this figure with the default budget below; per-solver budgets are
overrides in `solver_configs`.  Reports the shared Trace-derived
metrics (final gap, wall/communication cost to 1e-3 suboptimality).
"""
from __future__ import annotations

from typing import Dict, List

import jax

from benchmarks.common import (build_partitioned_problem,
                               build_registry_problem, reference_optimum,
                               trace_row)
from repro.core import solvers
from repro.core.solvers import SolverConfig

P_WORKERS = 8
EPS = 1e-3


def solver_configs(n_k: int) -> Dict[str, SolverConfig]:
    """Per-solver budgets matched to the seed benchmark settings."""
    return {
        # pSCOPE: M = 3 local epochs per outer round (eta per Cor. 1 scale)
        "pscope": SolverConfig(rounds=16, eta=1.2, inner_epochs=3.0),
        "pscope_lazy": SolverConfig(rounds=16, eta=1.2, inner_epochs=3.0),
        "fista": SolverConfig(rounds=120),
        "pgd": SolverConfig(rounds=120),
        "prox_svrg": SolverConfig(rounds=12, eta=0.5, inner_epochs=2.0),
        "dpsgd": SolverConfig(rounds=20, record_every=20, eta=0.5, batch=8),
        "dpsvrg": SolverConfig(rounds=12, eta=0.5,
                               extras={"inner_steps": n_k}),
        "admm": SolverConfig(rounds=40, extras={"rho": 1.0}),
        "owlqn": SolverConfig(rounds=60),
        "dbcd": SolverConfig(rounds=120),
        "cocoa": SolverConfig(rounds=60),
    }


# --smoke: the telemetry CI leg — just enough work to light up the
# ingest -> partition -> solve span chain in a trace, not a benchmark
SMOKE_SOLVERS = ("pscope", "pscope_lazy")
SMOKE_CFG = SolverConfig(rounds=3, eta=1.2, inner_epochs=1.0)


def run_dataset(ds: str, model: str, scale: float = 0.05,
                registry: bool = False, smoke: bool = False) -> List[Dict]:
    build = build_registry_problem if registry else build_partitioned_problem
    obj, reg, part = build(ds, model, p=P_WORKERS, scale=scale)
    p_star = reference_optimum(obj, reg, part.X, part.y)
    cfgs = solver_configs(part.n_k)
    rows = []
    for name in solvers.available():
        if smoke and name not in SMOKE_SOLVERS:
            continue
        if name == "pscope_mesh" and jax.device_count() < part.p:
            # needs one device per worker (real meshes / forced-device
            # runs); benchmarks/bench_comm.py covers it in a child
            continue
        cfg = SMOKE_CFG if smoke else cfgs.get(name, SolverConfig(rounds=30))
        trace = solvers.run(name, obj, reg, part, cfg)
        rows.append(trace_row(trace, f"fig1/{ds}/{model}", p_star, EPS))
    return rows


def main(full: bool = False, dataset: str = None,
         smoke: bool = False) -> List[Dict]:
    if dataset is not None:
        # a `repro.datasets` registry name ("rcv1-like", ...): the data
        # arrives through the real LIBSVM parse -> mmap shard path, and
        # the model follows the profile's task
        from repro import datasets as registry
        return run_dataset(dataset, registry.get(dataset).model,
                           scale=0.05, registry=True, smoke=smoke)
    rows = []
    datasets = ["cov", "rcv1"] + (["avazu", "kdd2012"] if full else [])
    if smoke:
        datasets = datasets[:1]
    for ds in datasets:
        for model in ("logistic", "lasso"):
            rows.extend(run_dataset(ds, model,
                                    scale=0.05 if ds in ("cov", "rcv1")
                                    else 0.02, smoke=smoke))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(main())
