"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only substr]
    PYTHONPATH=src python -m benchmarks.run --list-solvers
    PYTHONPATH=src python -m benchmarks.run --only lazy_inner --json

Every solver-comparison figure sweeps the `core.solvers` registry via
its single `solvers.run` entry point; `--list-solvers` prints the
registry.  Emits ``name,us_per_call,derived`` CSV (one row per
measurement).  ``--json`` additionally writes the machine-readable
perf-trail snapshots (us_per_call per row) so the perf trajectory is
diffable across PRs: BENCH_inner_loop.json from the ``inner_loop/*``
rows — ``dense``, the PR-2 ``lazy`` reference scan, the epoch-planned
``fused`` engine, and the cost-model ``auto`` dispatch: four rows per
(d, density) cell — BENCH_partition.json from the ``partition/*`` rows,
and BENCH_ingest.json from the ``ingest/*`` LIBSVM-pipeline throughput
rows.  ``--dataset rcv1-like`` reroutes fig1/table2 through the
`repro.datasets` registry (real LIBSVM text -> mmap shards).
"""
import argparse
import json
import sys
import traceback


def list_solvers() -> None:
    from repro.core import solvers
    print(f"{'name':12s} {'dist':5s} {'paper ref':46s} communication")
    for name in solvers.available():
        spec = solvers.get(name)
        dist = "p-way" if spec.distributed else "flat"
        print(f"{name:12s} {dist:5s} {spec.paper_ref:46s} {spec.comm_model}")


# cross-PR perf trails: row-name prefix -> snapshot file.  Each file
# only ever absorbs its own prefix, so a `--json` run that selected
# other suites cannot clobber an unrelated trail.
JSON_TRAILS = {
    "inner_loop/": "BENCH_inner_loop.json",
    "partition/": "BENCH_partition.json",
    "ingest/": "BENCH_ingest.json",
    "comm/": "BENCH_comm.json",
    "elastic/": "BENCH_elastic.json",
}


def write_json(rows, path) -> None:
    """Write every perf trail whose prefix collected rows.

    `path` overrides the destination when exactly one trail matched
    (the historical --json PATH behavior); with several trails matched
    the per-trail default filenames are used.

    Schema bench-rows/v2: every row is stamped through
    `benchmarks.common.stamp_row` — host fingerprint, backend,
    timestamp, and a `pct_peak` roofline annotation (None when the row
    carries no byte model) — and the file carries one shared
    `host` block so a trail diff can tell code from container.
    """
    from benchmarks.common import host_fingerprint, stamp_row
    matched = {}
    for prefix, default_path in JSON_TRAILS.items():
        trail_rows = [r for r in rows if r["name"].startswith(prefix)]
        if trail_rows:
            matched[default_path] = trail_rows
    if not matched:
        trails = ", ".join(JSON_TRAILS)
        print(f"no perf-trail rows collected (prefixes: {trails}); "
              "not writing JSON (run with --only lazy_inner or "
              "--only partition)", file=sys.stderr)
        return
    for default_path, trail_rows in matched.items():
        out = path if (path and len(matched) == 1) else default_path
        trail_rows = [stamp_row(r) for r in trail_rows]
        us = {}
        for r in trail_rows:
            try:
                us[r["name"]] = float(r.get("us_per_call", ""))
            except (TypeError, ValueError):
                continue
        doc = {"schema": "bench-rows/v2", "us_per_call": us,
               "host": host_fingerprint(), "rows": trail_rows}
        with open(out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out} ({len(us)} timed rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the large avazu/kdd-like datasets")
    ap.add_argument("--only", default="")
    ap.add_argument("--list-solvers", action="store_true",
                    help="print the solver registry and exit")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also snapshot the perf-trail rows as JSON "
                         "(BENCH_inner_loop.json / BENCH_partition.json / "
                         "BENCH_ingest.json; PATH overrides when a single "
                         "trail matched)")
    ap.add_argument("--dataset", default=None, metavar="NAME",
                    help="run fig1/table2 on a repro.datasets registry "
                         "dataset (e.g. rcv1-like): real LIBSVM text "
                         "through the mmap ingestion path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast cells (CI): fig1 runs two solvers "
                         "few rounds, lazy_inner one small cell")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's telemetry spans/counters as "
                         "Chrome-trace JSON (Perfetto-loadable)")
    args = ap.parse_args()

    if args.list_solvers:
        list_solvers()
        return

    from benchmarks import (fig1_convergence, table2_timing, fig2a_speedup,
                            fig2b_partition, recovery_bench, roofline_report,
                            bench_lazy_inner, bench_partition, bench_ingest,
                            bench_shard_codec, bench_comm, bench_elastic)
    suites = [
        ("fig1", lambda: fig1_convergence.main(full=args.full,
                                               dataset=args.dataset,
                                               smoke=args.smoke)),
        ("table2", lambda: table2_timing.main(dataset=args.dataset)),
        ("fig2a", fig2a_speedup.main),
        ("fig2b", fig2b_partition.main),
        ("recovery", recovery_bench.main),
        ("roofline", roofline_report.main),
        ("lazy_inner", lambda: bench_lazy_inner.main(full=args.full,
                                                     smoke=args.smoke)),
        ("partition", lambda: bench_partition.main(full=args.full)),
        ("ingest", lambda: bench_ingest.main(full=args.full)),
        ("ingest_codec", lambda: bench_shard_codec.main(full=args.full)),
        ("comm", lambda: bench_comm.main(full=args.full)),
        ("elastic", lambda: bench_elastic.main(full=args.full)),
    ]
    rows = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            rows.extend(fn())
        except Exception:
            traceback.print_exc()
            rows.append({"name": f"{name}/FAILED", "us_per_call": "",
                         "derived": "see stderr"})
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},"
              f"{r.get('derived', '')}")
    if args.json is not None:
        write_json(rows, args.json or None)
    if args.trace_out:
        from repro import obs
        obs.write_trace(args.trace_out)
        print(f"wrote {args.trace_out} "
              f"({len(obs.get_collector().events())} telemetry events)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
