"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only substr]

Emits ``name,us_per_call,derived`` CSV (one row per measurement).
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the large avazu/kdd-like datasets")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (fig1_convergence, table2_timing, fig2a_speedup,
                            fig2b_partition, recovery_bench, roofline_report)
    suites = [
        ("fig1", lambda: fig1_convergence.main(full=args.full)),
        ("table2", table2_timing.main),
        ("fig2a", fig2a_speedup.main),
        ("fig2b", fig2b_partition.main),
        ("recovery", recovery_bench.main),
        ("roofline", roofline_report.main),
    ]
    rows = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            rows.extend(fn())
        except Exception:
            traceback.print_exc()
            rows.append({"name": f"{name}/FAILED", "us_per_call": "",
                         "derived": "see stderr"})
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},"
              f"{r.get('derived', '')}")


if __name__ == "__main__":
    main()
