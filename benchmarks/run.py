"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only substr]
    PYTHONPATH=src python -m benchmarks.run --list-solvers
    PYTHONPATH=src python -m benchmarks.run --only lazy_inner --json

Every solver-comparison figure sweeps the `core.solvers` registry via
its single `solvers.run` entry point; `--list-solvers` prints the
registry.  Emits ``name,us_per_call,derived`` CSV (one row per
measurement).  ``--json`` additionally writes BENCH_inner_loop.json —
a machine-readable snapshot (us_per_call per solver/path) so the perf
trajectory is diffable across PRs.
"""
import argparse
import json
import sys
import traceback


def list_solvers() -> None:
    from repro.core import solvers
    print(f"{'name':12s} {'dist':5s} {'paper ref':46s} communication")
    for name in solvers.available():
        spec = solvers.get(name)
        dist = "p-way" if spec.distributed else "flat"
        print(f"{name:12s} {dist:5s} {spec.paper_ref:46s} {spec.comm_model}")


def write_json(rows, path: str) -> None:
    """BENCH_inner_loop.json: the inner_loop/* rows + a name -> us map.

    Only the lazy_inner suite's rows are snapshotted — the file is the
    cross-PR inner-loop perf trail, so a `--json` run that selected
    other suites must not clobber it with unrelated rows.
    """
    rows = [r for r in rows if r["name"].startswith("inner_loop/")]
    if not rows:
        print(f"no inner_loop rows collected; not writing {path} "
              "(run with --only lazy_inner)", file=sys.stderr)
        return
    us = {}
    for r in rows:
        try:
            us[r["name"]] = float(r.get("us_per_call", ""))
        except (TypeError, ValueError):
            continue
    doc = {"schema": "bench-rows/v1", "us_per_call": us, "rows": rows}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(us)} timed rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the large avazu/kdd-like datasets")
    ap.add_argument("--only", default="")
    ap.add_argument("--list-solvers", action="store_true",
                    help="print the solver registry and exit")
    ap.add_argument("--json", nargs="?", const="BENCH_inner_loop.json",
                    default=None, metavar="PATH",
                    help="also write the rows as JSON "
                         "(default: BENCH_inner_loop.json)")
    args = ap.parse_args()

    if args.list_solvers:
        list_solvers()
        return

    from benchmarks import (fig1_convergence, table2_timing, fig2a_speedup,
                            fig2b_partition, recovery_bench, roofline_report,
                            bench_lazy_inner)
    suites = [
        ("fig1", lambda: fig1_convergence.main(full=args.full)),
        ("table2", table2_timing.main),
        ("fig2a", fig2a_speedup.main),
        ("fig2b", fig2b_partition.main),
        ("recovery", recovery_bench.main),
        ("roofline", roofline_report.main),
        ("lazy_inner", lambda: bench_lazy_inner.main(full=args.full)),
    ]
    rows = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            rows.extend(fn())
        except Exception:
            traceback.print_exc()
            rows.append({"name": f"{name}/FAILED", "us_per_call": "",
                         "derived": "see stderr"})
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},"
              f"{r.get('derived', '')}")
    if args.json:
        write_json(rows, args.json)


if __name__ == "__main__":
    main()
