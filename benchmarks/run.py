"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only substr]
    PYTHONPATH=src python -m benchmarks.run --list-solvers

Every solver-comparison figure sweeps the `core.solvers` registry via
its single `solvers.run` entry point; `--list-solvers` prints the
registry.  Emits ``name,us_per_call,derived`` CSV (one row per
measurement).
"""
import argparse
import sys
import traceback


def list_solvers() -> None:
    from repro.core import solvers
    print(f"{'name':10s} {'dist':5s} {'paper ref':42s} communication")
    for name in solvers.available():
        spec = solvers.get(name)
        dist = "p-way" if spec.distributed else "flat"
        print(f"{name:10s} {dist:5s} {spec.paper_ref:42s} {spec.comm_model}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the large avazu/kdd-like datasets")
    ap.add_argument("--only", default="")
    ap.add_argument("--list-solvers", action="store_true",
                    help="print the solver registry and exit")
    args = ap.parse_args()

    if args.list_solvers:
        list_solvers()
        return

    from benchmarks import (fig1_convergence, table2_timing, fig2a_speedup,
                            fig2b_partition, recovery_bench, roofline_report)
    suites = [
        ("fig1", lambda: fig1_convergence.main(full=args.full)),
        ("table2", table2_timing.main),
        ("fig2a", fig2a_speedup.main),
        ("fig2b", fig2b_partition.main),
        ("recovery", recovery_bench.main),
        ("roofline", roofline_report.main),
    ]
    rows = []
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            rows.extend(fn())
        except Exception:
            traceback.print_exc()
            rows.append({"name": f"{name}/FAILED", "us_per_call": "",
                         "derived": "see stderr"})
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},"
              f"{r.get('derived', '')}")


if __name__ == "__main__":
    main()
