"""Shard-codec benchmark: compression ratio, decode bandwidth, and the
storage-roofline picture of the fused-decode epoch gather.

Every row carries the ``ingest/`` prefix, so ``--json`` folds them into
BENCH_ingest.json next to the parse/shard throughput trail:

    ingest/codec/ratio/<ds>     store bytes, raw vs delta+bf16
    ingest/codec/decode/<ds>    packed -> padded-CSR decode bandwidth
    ingest/codec/gather/<ds>    run_scanned epoch over PRE-BUILT
                                containers (data resident, equal logical
                                bytes) — the "fused-decode gather is no
                                slower" check
    ingest/codec/epoch/<ds>/nvme      end-to-end epoch (open ->
                                materialize -> solve) with pages evicted
                                per repeat: local-NVMe storage, where
                                compute dominates and the codec buys
                                nothing (reported honestly)
    ingest/codec/epoch/<ds>/streamed  the regime the codec exists for:
                                shard bytes physically streamed in at an
                                emulated network/object-storage
                                bandwidth (paced chunk reads,
                                EMU_BW_MB_S) before the epoch, the whole
                                thing timed — storage bytes dominate, so
                                the 3-4x byte reduction turns into
                                end-to-end epoch speedup
    ingest/codec/roofline/<ds>  bytes-moved roofline (dace
                                roofline_model idiom): measured storage
                                and compute terms per layout, predicted
                                streamed speedup, and the storage
                                bandwidth below which the codec wins
                                >=1.5x end to end

    PYTHONPATH=src python -m benchmarks.bench_shard_codec [--smoke|--full]
    PYTHONPATH=src python -m benchmarks.run --only ingest --json
"""
from __future__ import annotations

import os
import shutil
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro import datasets

EPOCH_KW = dict(eta=0.5, inner_steps=8, inner_batch=1, outer_steps=1,
                seed=0, inner_path="lazy")
REPEATS = 5
EMU_BW_MB_S = 16.0      # contended NFS / cold object storage figure
_CHUNK = 256 << 10


def _evict(root: Path) -> None:
    """Drop the page cache for every file under `root` (Linux)."""
    if not hasattr(os, "posix_fadvise"):
        return
    for f in root.iterdir():
        fd = os.open(f, os.O_RDONLY)
        try:
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        finally:
            os.close(fd)


def _stream_in(src: Path, dst: Path, mb_per_s: float) -> int:
    """Physically copy the store at a paced bandwidth (emulated remote
    storage: the epoch cannot start on bytes that have not arrived)."""
    shutil.rmtree(dst, ignore_errors=True)
    dst.mkdir(parents=True)
    total = 0
    t0 = time.perf_counter()
    for f in sorted(src.iterdir()):
        with open(f, "rb") as fi, open(dst / f.name, "wb") as fo:
            while True:
                buf = fi.read(_CHUNK)
                if not buf:
                    break
                fo.write(buf)
                total += len(buf)
                ahead = total / (mb_per_s * 1e6) - (time.perf_counter() - t0)
                if ahead > 0:
                    time.sleep(ahead)
    return total


def _build_pair(fixture: Path, name: str, p: int, d: int):
    """Raw + delta+bf16 stores ingested from the same fixture text."""
    outs = []
    for codec in (None, "delta+bf16"):
        out = fixture.parent / f"_codecbench.{name}.{codec or 'raw'}"
        shutil.rmtree(out, ignore_errors=True)
        outs.append(datasets.ingest_libsvm(fixture, out, p, n_features=d,
                                           zero_based=False, codec=codec))
    return outs


def _solver():
    import jax.numpy as jnp
    from repro.core import LOGISTIC, PScopeConfig, Regularizer
    from repro.core.pscope import run_scanned
    cfg = PScopeConfig(**EPOCH_KW)
    reg = Regularizer(1e-4, 1e-4)

    def solve_xp(Xp, yp, d):
        return run_scanned(LOGISTIC, reg, Xp, yp, jnp.zeros(d), cfg)

    def solve(st):
        Xp = st.enc_p if st.codec is not None else st.csr_p
        return solve_xp(Xp, np.asarray(st.yp), st.d)
    return solve, solve_xp


def _epoch_seconds(root: Path, solve, mode: str) -> float:
    """Min wall seconds of one full epoch over a stored shard.

    mode='warm'  open -> materialize -> solve, page-cache hot
    mode='nvme'  same, pages evicted first (real local-storage fault-in)
    mode='streamed'  shard bytes paced in at EMU_BW_MB_S first, then the
                     epoch — both timed as one unit
    """
    from repro.datasets.shards import open_store
    solve(open_store(root))                  # compile + warm the cache
    stream_dst = root.parent / f"{root.name}.streamed"
    ts = []
    for _ in range(REPEATS if mode != "streamed" else 3):
        if mode == "nvme":
            _evict(root)
        t0 = time.perf_counter()
        if mode == "streamed":
            _stream_in(root, stream_dst, EMU_BW_MB_S)
            solve(open_store(stream_dst))
        else:
            solve(open_store(root))
        ts.append(time.perf_counter() - t0)
    shutil.rmtree(stream_dst, ignore_errors=True)
    return float(np.min(ts))


def _gather_seconds(root: Path, solve_xp) -> float:
    """The equal-bytes cell: containers pre-built and resident, so this
    times only the epoch itself (plan + gathers + inner scan)."""
    from repro.datasets.shards import open_store
    st = open_store(root)
    Xp = st.enc_p if st.codec is not None else st.csr_p
    yp = np.asarray(st.yp)
    solve_xp(Xp, yp, st.d)
    ts = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        solve_xp(Xp, yp, st.d)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def _decode_row(name: str, enc) -> Dict:
    """Bandwidth of the packed -> padded decode (page-cache hot)."""
    from repro.datasets.shards import open_store
    packed = sum(enc.segment_extent(k, w)[1]
                 for k in ("vals", "cols") for w in range(enc.p))
    decoded = 0
    best = np.inf
    for _ in range(REPEATS):
        st = open_store(enc.root)            # fresh: views cache decodes
        t0 = time.perf_counter()
        decoded = np.asarray(st.vals).nbytes + np.asarray(st.cols).nbytes
        best = min(best, time.perf_counter() - t0)
    return {
        "name": f"ingest/codec/decode/{name}",
        "us_per_call": f"{best * 1e6:.0f}",
        "derived": (f"decoded_gb_per_s={decoded / best / 1e9:.2f};"
                    f"packed_gb_per_s={packed / best / 1e9:.2f};"
                    f"packed_mb={packed / 1e6:.2f};"
                    f"decoded_mb={decoded / 1e6:.2f}"),
    }


def _crossover_bw(c_raw, c_enc, b_raw, b_enc, target=1.5) -> float:
    """Storage bandwidth (MB/s) below which the codec's end-to-end
    epoch speedup exceeds `target`:  (c_raw + b_raw/bw) >=
    target * (c_enc + b_enc/bw)  solved for bw."""
    num = b_raw / 1e6 - target * b_enc / 1e6
    den = target * c_enc - c_raw
    if num <= 0:
        return 0.0
    return num / den if den > 0 else float("inf")


def bench_dataset(name: str, scale: float, p: int = 8) -> List[Dict]:
    prof = datasets.get(name)
    fixture = datasets.ensure_fixture(name, scale=scale)
    raw, enc = _build_pair(fixture, name, p, prof.d)
    solve, solve_xp = _solver()
    rows = [{
        "name": f"ingest/codec/ratio/{name}",
        "us_per_call": "",
        "derived": (f"raw_mb={raw.nbytes / 1e6:.2f};"
                    f"codec_mb={enc.nbytes / 1e6:.2f};"
                    f"ratio={raw.nbytes / enc.nbytes:.2f};"
                    f"rows={raw.p * raw.n_k};max_nnz={raw.max_nnz}"),
    }, _decode_row(name, enc)]

    t_raw_g = _gather_seconds(raw.root, solve_xp)
    t_enc_g = _gather_seconds(enc.root, solve_xp)
    rows.append({
        "name": f"ingest/codec/gather/{name}",
        "us_per_call": f"{t_enc_g * 1e6:.0f}",
        "derived": (f"raw_us={t_raw_g * 1e6:.0f};"
                    f"codec_over_raw={t_enc_g / t_raw_g:.3f}"),
    })

    t_raw_w = _epoch_seconds(raw.root, solve, "warm")
    t_enc_w = _epoch_seconds(enc.root, solve, "warm")
    t_raw_n = _epoch_seconds(raw.root, solve, "nvme")
    t_enc_n = _epoch_seconds(enc.root, solve, "nvme")
    rows.append({
        "name": f"ingest/codec/epoch/{name}/nvme",
        "us_per_call": f"{t_enc_n * 1e6:.0f}",
        "derived": (f"raw_us={t_raw_n * 1e6:.0f};"
                    f"speedup={t_raw_n / t_enc_n:.2f}"),
    })
    t_raw_s = _epoch_seconds(raw.root, solve, "streamed")
    t_enc_s = _epoch_seconds(enc.root, solve, "streamed")
    rows.append({
        "name": f"ingest/codec/epoch/{name}/streamed",
        "us_per_call": f"{t_enc_s * 1e6:.0f}",
        "derived": (f"raw_us={t_raw_s * 1e6:.0f};"
                    f"speedup={t_raw_s / t_enc_s:.2f};"
                    f"emulated_storage_mb_per_s={EMU_BW_MB_S:g}"),
    })

    # dace-style roofline: t = t_compute + bytes/BW per layout; the
    # compute term is the measured warm epoch (storage term ~0 there)
    bw = EMU_BW_MB_S * 1e6
    pred = ((t_raw_w + raw.nbytes / bw)
            / (t_enc_w + enc.nbytes / bw))
    cross = _crossover_bw(t_raw_w, t_enc_w, raw.nbytes, enc.nbytes)
    rows.append({
        "name": f"ingest/codec/roofline/{name}",
        "us_per_call": "",
        "derived": (f"t_comp_raw={t_raw_w:.4f};t_comp_codec={t_enc_w:.4f};"
                    f"bytes_raw_mb={raw.nbytes / 1e6:.2f};"
                    f"bytes_codec_mb={enc.nbytes / 1e6:.2f};"
                    f"predicted_streamed_speedup={pred:.2f};"
                    f"crossover_bw_for_1.5x_mb_per_s={cross:.1f}"),
    })
    shutil.rmtree(raw.root, ignore_errors=True)
    shutil.rmtree(enc.root, ignore_errors=True)
    return rows


def _smoke() -> List[Dict]:
    """CI gate: ratio + bitwise equality of the decoded views on a tiny
    fixture pair, then the ratio row only (no timing on shared runners)."""
    name, scale, p = "rcv1-like", 0.02, 4
    prof = datasets.get(name)
    fixture = datasets.ensure_fixture(name, scale=scale)
    raw, enc = _build_pair(fixture, name, p, prof.d)
    assert raw.nbytes / enc.nbytes >= 2.5, \
        f"compression ratio {raw.nbytes / enc.nbytes:.2f}x < 2.5x"
    for key in ("vals", "cols", "row_nnz", "yp", "members"):
        assert np.array_equal(np.asarray(getattr(raw, key)),
                              np.asarray(getattr(enc, key))), \
            f"codec store {key} drifted from raw"
    row = {
        "name": f"ingest/codec/ratio/{name}",
        "us_per_call": "",
        "derived": f"ratio={raw.nbytes / enc.nbytes:.2f};smoke=1",
    }
    shutil.rmtree(raw.root, ignore_errors=True)
    shutil.rmtree(enc.root, ignore_errors=True)
    return [row]


def main(full: bool = False, smoke: bool = False) -> List[Dict]:
    if smoke:
        return _smoke()
    grid = [("rcv1-like", 4.0), ("avazu-like", 2.0)]
    if full:
        grid += [("kdd2012-like", 2.0)]
    rows = []
    for name, scale in grid:
        rows.extend(bench_dataset(name, scale))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny ratio + bitwise-equality gate (CI)")
    ap.add_argument("--full", action="store_true",
                    help="include the kdd2012-scale fixture")
    args = ap.parse_args()
    from benchmarks.common import emit
    emit(main(full=args.full, smoke=args.smoke))
